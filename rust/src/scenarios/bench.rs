//! Benchmark/reproduction entry points — one per paper table/figure
//! (DESIGN.md experiment index). Shared by `hulk bench <name>` and
//! `cargo bench` (rust/benches/bench_main.rs). Formerly the standalone
//! `bench_impl.rs` include; now a library module inside the scenario
//! subsystem so both binaries compile it once.
//!
//! `hulk bench micro --json` additionally writes the wall-clock
//! microbenchmark means as `BENCH_micro.json` (benchkit reporting layer).
//! The *deterministic* perf trajectory comes from `hulk scenarios run all
//! --json`, not from here.

use anyhow::Result;

use crate::benchkit::{BenchConfig, BenchEntry, BenchReport, Bencher};
use crate::cli::Cli;
use crate::cluster::paper_data::{fig6_node_45, TABLE1_MS, TABLE1_RECEIVERS,
                                 TABLE1_SENDERS};
use crate::cluster::{Fleet, WanModel};
use crate::coordinator::{recover, RecoveryAction};
use crate::gnn::{make_dataset, train_gcn, RefGcn, RefGcnConfig,
                 TrainerOptions};
use crate::graph::{ClusterGraph, CsrGraph, HierarchicalGraph};
use crate::models::ModelSpec;
use crate::parallel::{pipeline_cost, PipelinePlan};
use crate::planner::{chain_order, CostBackend, HulkPlanner,
                     HulkSplitterKind, PlanContext, Planner,
                     PlannerRegistry, SystemAPlanner};
use crate::runtime::client::TrainState;
use crate::runtime::{GcnRuntime, Manifest};
use crate::scheduler::{oracle_partition, OracleOptions};
use crate::sim::{execute_placement, simulate_pipeline};

use super::evaluate::evaluate_all;
use super::world::ScenarioWorld;
use crate::util::rng::Rng;
use crate::util::table::{fmt_ms, fmt_params, Table};

pub fn run(names: &[String], cli: &Cli) -> Result<()> {
    let list: Vec<&str> = if names.is_empty()
        || names.iter().any(|n| n == "all")
    {
        vec!["table1", "logs", "fig4", "fig5", "fig6", "table2", "fig8",
             "fig9", "fig10", "ablation", "sweep", "micro"]
    } else {
        names.iter().map(String::as_str).collect()
    };
    for name in list {
        println!("\n================ {name} ================");
        match name {
            "table1" => table1(cli)?,
            "table2" => table2(cli)?,
            "logs" => logs(cli)?,
            "fig4" => fig4(cli)?,
            "fig5" => fig5(cli)?,
            "fig6" => fig6(cli)?,
            "fig8" => fig8(cli)?,
            "fig9" => fig9()?,
            "fig10" => fig10(cli)?,
            "ablation" => ablation(cli)?,
            "sweep" => sweep(cli)?,
            "micro" => micro(cli)?,
            other => anyhow::bail!("unknown bench {other:?}"),
        }
    }
    Ok(())
}

/// The paper's raw-measurement path: 3 months of synthetic communication
/// logs per Table 1 pair → trimmed-mean estimate → compare to the
/// measured value the table reports.
fn logs(cli: &Cli) -> Result<()> {
    use crate::cluster::logs::{estimate_latency, generate_logs,
                               log_summary};
    let wan = WanModel::new(cli.flag_u64("seed", 0)?);
    let days = cli.flag_u64("days", 90)? as usize;
    let samples = cli.flag_u64("samples", 2000)? as usize;
    let mut t = Table::new(&["pair", "log mean", "log p95", "trimmed est",
                             "Table 1"]);
    for &sender in TABLE1_SENDERS.iter() {
        for &receiver in TABLE1_RECEIVERS.iter() {
            let Some(series) =
                generate_logs(&wan, sender, receiver, days, samples)
            else {
                t.row(&[format!("{sender} → {receiver}"), "-".into(),
                        "-".into(), "-".into(), "blocked".into()]);
                continue;
            };
            let s = log_summary(&series);
            let est = estimate_latency(&series);
            let table1 = wan.latency_ms(sender, receiver).unwrap();
            t.row(&[
                format!("{sender} → {receiver}"),
                format!("{:.1}", s.mean),
                format!("{:.1}", s.p95),
                format!("{est:.1}"),
                format!("{table1:.1}"),
            ]);
        }
    }
    println!("{}", t.render());
    println!("({days} days, {samples} probes/pair; trimmed mean drops the \
              top 5% congestion spikes — the estimates recover Table 1)");
    Ok(())
}

/// DESIGN.md ablation sweeps: fleet size, microbatches, WAN degradation.
fn sweep(cli: &Cli) -> Result<()> {
    use super::sweep::{fleet_size_sweep, microbatch_sweep,
                       wan_degradation_sweep};
    let seed = cli.flag_u64("seed", 0)?;
    let planners = PlannerRegistry::standard();

    println!("— fleet-size sweep (Hulk improvement vs best baseline) —");
    let mut t = Table::new(&["servers", "improvement"]);
    for p in fleet_size_sweep(&planners, CostBackend::Analytic, seed,
                              &[12, 16, 24, 32, 46],
                              &ModelSpec::paper_four())? {
        t.row(&[format!("{:.0}", p.x),
                format!("{:.1}%", p.improvement * 100.0)]);
    }
    println!("{}", t.render());

    println!("— microbatch sweep (GPT-2 Hulk group, per-iter total) —");
    let mut t = Table::new(&["K", "iter total"]);
    for p in microbatch_sweep(&planners, CostBackend::Analytic, seed,
                              &ModelSpec::gpt2_xl(),
                              &[1, 2, 4, 8, 16, 32])? {
        t.row(&[format!("{:.0}", p.x), fmt_ms(p.improvement)]);
    }
    println!("{}", t.render());

    println!("— WAN degradation sweep (all inter-region latencies ×f) —");
    let mut t = Table::new(&["factor", "improvement"]);
    for p in wan_degradation_sweep(&planners, CostBackend::Analytic, seed,
                                   &[1.0, 2.0, 4.0, 8.0],
                                   &ModelSpec::paper_four())? {
        t.row(&[format!("×{:.0}", p.x),
                format!("{:.1}%", p.improvement * 100.0)]);
    }
    println!("{}", t.render());
    Ok(())
}

/// Table 1: ms per 64-byte message, averaged over 10 sampled
/// communications per pair (the paper's measurement procedure), plus the
/// measured seed values for comparison.
fn table1(cli: &Cli) -> Result<()> {
    let wan = WanModel::new(cli.flag_u64("seed", 0)?);
    let mut t = Table::new(&["Regions", "California", "Tokyo", "Berlin",
                             "London", "New Delhi", "Paris", "Rome",
                             "Brasilia"]);
    for (r, &sender) in TABLE1_SENDERS.iter().enumerate() {
        let mut row = vec![sender.name().to_string()];
        for (c, &receiver) in TABLE1_RECEIVERS.iter().enumerate() {
            let cell = match wan.latency_ms(sender, receiver) {
                None => "-".to_string(),
                Some(_) => {
                    let mean: f64 = (0..10)
                        .map(|trial| {
                            wan.sample_latency_ms(sender, receiver, trial)
                                .unwrap()
                        })
                        .sum::<f64>()
                        / 10.0;
                    let paper = TABLE1_MS[r][c]
                        .map(|v| format!(" (paper {v})"))
                        .unwrap_or_default();
                    format!("{mean:.1}{paper}")
                }
            };
            row.push(cell);
        }
        t.row(&row);
    }
    println!("{}", t.render());
    println!("(sampled mean of 10 trials; 'paper' = Table 1 measured seed)");
    Ok(())
}

/// Table 2 / Fig. 7: node allocation of the 46-server fleet for the
/// four-model workload.
fn table2(cli: &Cli) -> Result<()> {
    let fleet = Fleet::paper_evaluation(cli.flag_u64("seed", 0)?);
    let graph = ClusterGraph::from_fleet(&fleet);
    let mut tasks = ModelSpec::paper_four();
    ModelSpec::sort_largest_first(&mut tasks);
    let a = oracle_partition(&fleet, &graph, &tasks,
                             &OracleOptions::default());
    println!("{}", a.render_table(&tasks));
    let spares = a.spares(fleet.len());
    println!("spares (recovery pool): {spares:?}");
    println!("total intra-group comm cost: {:.0} ms·edges",
             a.total_cost(&graph));
    println!("(paper Table 2 allocates 39 of 46 nodes across the 4 models)");
    Ok(())
}

/// Fig. 4: GCN loss/accuracy over 10 training steps (lr 0.01, ~188k
/// params) — trained from Rust through the PJRT train_step artifact.
fn fig4(cli: &Cli) -> Result<()> {
    let rt = GcnRuntime::load(&Manifest::default_dir())?;
    println!("PJRT platform {}; {} params (paper: 188k); lr 0.01",
             rt.platform(), rt.manifest.p);
    let seed = cli.flag_u64("seed", 0)?;
    // Paper Fig. 4 shows 10 steps to 99%; our features are weaker than
    // whatever the authors hand-labeled against (their data is
    // unreleased), so the same curve stretches to ~60 steps. The default
    // shows the full convergence; pass --steps 10 for the paper's window.
    let steps = cli.flag_u64("steps", 60)? as u32;
    // Fig. 4 trains on "this data" — the single labeled cluster graph
    // (§3–§4), i.e. the supervised overfit regime, not a corpus.
    let fleet = Fleet::paper_evaluation(seed);
    let dataset = vec![crate::gnn::LabeledGraph::from_fleet(
        &fleet, &ModelSpec::paper_four(), rt.manifest.n)];
    let mut state = TrainState::fresh(rt.manifest.load_init_params()?);
    let opts = TrainerOptions { steps, lr: 0.01, log_every: 0 };
    let t0 = std::time::Instant::now();
    let curve = train_gcn(&rt, &mut state, &dataset, &opts)?;
    let wall = t0.elapsed().as_secs_f64();
    let mut t = Table::new(&["step", "loss", "accuracy"]);
    for p in &curve {
        t.row(&[p.step.to_string(), format!("{:.4}", p.loss),
                format!("{:.3}", p.acc)]);
    }
    println!("{}", t.render());
    let best = curve.iter().map(|p| p.acc).fold(0.0f32, f32::max);
    println!("best acc {best:.3} in {steps} steps \
              ({:.1} ms/step wall)", wall * 1e3 / steps as f64);
    println!("(paper Fig. 4 peaks at 99% by step 6 on its unreleased \
              labeled data; see EXPERIMENTS.md)");
    Ok(())
}

/// Fig. 5: the 8-node toy graph grouped for GPT-2 vs BERT-large.
fn fig5(cli: &Cli) -> Result<()> {
    let fleet = Fleet::paper_toy(cli.flag_u64("seed", 0)?);
    let graph = ClusterGraph::from_fleet(&fleet);
    let tasks = vec![ModelSpec::gpt2_xl(), ModelSpec::bert_large()];
    let a = oracle_partition(&fleet, &graph, &tasks,
                             &OracleOptions::default());
    println!("{}", a.render_table(&tasks));
    for (t, group) in a.groups.iter().enumerate() {
        let labels: Vec<String> = group
            .iter()
            .map(|&m| format!("{}:{}", m, fleet.machines[m].label()))
            .collect();
        println!("task {t} ({}) group: {}", tasks[t].name,
                 labels.join("  "));
    }
    println!("(paper Fig. 5: left = GPT-2 group, right = BERT-large group; \
              sizes track the 4.4:1 parameter ratio)");
    Ok(())
}

/// Fig. 6: scale-out — node 45 {Rome, 7, 384} joins and gets assigned.
/// The join procedure itself is shared with the `fleet_growth` scenario
/// (`registry::fig6_scale_out`).
fn fig6(cli: &Cli) -> Result<()> {
    let seed = cli.flag_u64("seed", 0)?;
    let (fleet, a, tasks, id, placed, before_cost) =
        super::registry::fig6_scale_out(seed);
    let graph2 = ClusterGraph::from_fleet(&fleet);
    println!("joined machine {id} {}", fig6_node_45().label());
    match placed {
        Some(t) => println!("→ assigned to task {t} ({})", tasks[t].name),
        None => println!("→ kept as spare (recovery pool)"),
    }
    a.validate_disjoint(fleet.len()).map_err(|e| anyhow::anyhow!(e))?;
    a.validate_memory(&fleet, &tasks).map_err(|e| anyhow::anyhow!(e))?;
    println!("assignment still valid ✓ (intra-group cost {:.0} → {:.0})",
             before_cost, a.total_cost(&graph2));
    Ok(())
}

fn eval_workload(cli: &Cli, workload: Vec<ModelSpec>) -> Result<()> {
    let fleet = Fleet::paper_evaluation(cli.flag_u64("seed", 0)?);
    let eval = if cli.flag_bool("gnn") {
        let rt = GcnRuntime::load(&Manifest::default_dir())?;
        let mut state = TrainState::fresh(rt.manifest.load_init_params()?);
        let dataset = make_dataset(16, rt.manifest.n, 0);
        train_gcn(&rt, &mut state, &dataset,
                  &TrainerOptions { steps: 60, lr: 0.01, log_every: 0 })?;
        let params = state.params.clone();
        let classifier = crate::gnn::Classifier::Runtime(rt);
        evaluate_all(&fleet, &workload,
                     HulkSplitterKind::Gnn { classifier: &classifier,
                                             params: &params })?
    } else {
        evaluate_all(&fleet, &workload, HulkSplitterKind::Oracle)?
    };
    println!("{}", eval.render());
    println!("Hulk total-time improvement over best feasible baseline: \
              {:.1}% (paper claims >20%)",
             eval.hulk_improvement() * 100.0);
    Ok(())
}

/// Fig. 8: comm + comp time, 4 models × 4 systems.
fn fig8(cli: &Cli) -> Result<()> {
    eval_workload(cli, ModelSpec::paper_four())
}

/// Fig. 9: parameter counts of the six models.
fn fig9() -> Result<()> {
    let mut t = Table::new(&["model", "parameters"]);
    for m in ModelSpec::paper_six() {
        t.row(&[m.name.to_string(), fmt_params(m.params)]);
    }
    println!("{}", t.render());
    Ok(())
}

/// Fig. 10: comm + comp time, 6 models × 4 systems.
fn fig10(cli: &Cli) -> Result<()> {
    eval_workload(cli, ModelSpec::paper_six())
}

/// Ablations called out in DESIGN.md: analytic vs simulated pipeline
/// model; locality-aware chain order vs id order; recovery actions.
fn ablation(cli: &Cli) -> Result<()> {
    let seed = cli.flag_u64("seed", 0)?;
    let fleet = Fleet::paper_evaluation(seed);
    let graph = ClusterGraph::from_fleet(&fleet);
    let mut tasks = ModelSpec::paper_four();
    ModelSpec::sort_largest_first(&mut tasks);
    let a = oracle_partition(&fleet, &graph, &tasks,
                             &OracleOptions::default());

    println!("— analytic vs discrete-event pipeline model —");
    let mut t = Table::new(&["model", "analytic total", "sim makespan",
                             "ratio"]);
    for (i, task) in tasks.iter().enumerate() {
        let ordered = chain_order(&graph, a.group(i));
        let stages: Vec<usize> =
            ordered.into_iter().take(task.layers).collect();
        let plan = PipelinePlan::proportional(&fleet, stages, task);
        let analytic = pipeline_cost(&fleet, &plan, task);
        let sim = simulate_pipeline(&fleet, &plan, task, false, None);
        t.row(&[
            task.name.to_string(),
            fmt_ms(analytic.total_ms()),
            fmt_ms(sim.makespan_ms),
            format!("{:.2}", sim.makespan_ms / analytic.total_ms()),
        ]);
    }
    println!("{}", t.render());

    println!("— chain order (locality) vs id order, Hulk groups —");
    let mut t = Table::new(&["model", "id-order comm", "chain comm",
                             "gain"]);
    for (i, task) in tasks.iter().enumerate() {
        let group = a.group(i).to_vec();
        let n_stages = group.len().min(task.layers);
        let id_plan = PipelinePlan::proportional(
            &fleet, group[..n_stages].to_vec(), task);
        let ordered = chain_order(&graph, &group);
        let chain_plan = PipelinePlan::proportional(
            &fleet, ordered[..n_stages].to_vec(), task);
        let c_id = pipeline_cost(&fleet, &id_plan, task);
        let c_chain = pipeline_cost(&fleet, &chain_plan, task);
        t.row(&[
            task.name.to_string(),
            fmt_ms(c_id.comm_ms),
            fmt_ms(c_chain.comm_ms),
            format!("{:.2}×", c_id.comm_ms / c_chain.comm_ms.max(1e-9)),
        ]);
    }
    println!("{}", t.render());

    println!("— recovery actions over 20 random failures —");
    let mut rng = Rng::new(seed ^ 0xFA11);
    let mut counts = [0usize; 4];
    for _ in 0..20 {
        let mut a2 = a.clone();
        let victim = rng.below(fleet.len());
        let action = recover(&fleet, &graph, &mut a2, &tasks, victim);
        let idx = match action {
            RecoveryAction::PromoteSpare { .. } => 0,
            RecoveryAction::ShrinkGroup { .. } => 1,
            RecoveryAction::Requeue { .. } => 2,
            RecoveryAction::NoOp => 3,
        };
        counts[idx] += 1;
    }
    println!("promote-spare {} | shrink {} | requeue {} | noop(spare) {}",
             counts[0], counts[1], counts[2], counts[3]);
    Ok(())
}

/// Microbenchmarks of the L3 hot paths (benchkit). With `--json`, the
/// per-benchmark means are written as `BENCH_micro.json` under `--out`
/// (default `.`).
fn micro(cli: &Cli) -> Result<()> {
    let seed = cli.flag_u64("seed", 0)?;
    let fleet = Fleet::paper_evaluation(seed);
    let graph = ClusterGraph::from_fleet(&fleet);
    let tasks = {
        let mut t = ModelSpec::paper_four();
        ModelSpec::sort_largest_first(&mut t);
        t
    };
    let mut b = Bencher::new(BenchConfig::default());
    b.bench("graph_from_fleet_46", || ClusterGraph::from_fleet(&fleet));
    b.bench("oracle_partition_46x4", || {
        oracle_partition(&fleet, &graph, &tasks, &OracleOptions::default())
    });
    let a = oracle_partition(&fleet, &graph, &tasks,
                             &OracleOptions::default());
    b.bench("chain_order_largest_group", || {
        chain_order(&graph, a.group(0))
    });
    let ordered = chain_order(&graph, a.group(0));
    let plan = PipelinePlan::proportional(
        &fleet, ordered[..a.group(0).len().min(tasks[0].layers)].to_vec(),
        &tasks[0]);
    b.bench("pipeline_cost_opt_group", || {
        pipeline_cost(&fleet, &plan, &tasks[0])
    });
    b.bench("simulate_pipeline_opt_group", || {
        simulate_pipeline(&fleet, &plan, &tasks[0], false, None)
    });
    b.bench("evaluate_all_fig8", || {
        evaluate_all(&fleet, &tasks, HulkSplitterKind::Oracle).unwrap()
    });
    // DES event throughput.
    let sim = simulate_pipeline(&fleet, &plan, &tasks[0], false, None);
    let r = b.bench("sim_events_per_run", || {
        simulate_pipeline(&fleet, &plan, &tasks[0], false, None)
            .events_processed
    });
    println!("≈ {:.0} events/ms in the DES engine",
             sim.events_processed as f64 / r.summary.mean);

    // The `--cost sim` backend hot path: whole placements executed with
    // shared-link contention, on the Table 1 fleet and at planet scale.
    let ctx = PlanContext::new(&fleet, &graph, &tasks,
                               HulkSplitterKind::Oracle);
    let table1_placement = HulkPlanner.plan(&ctx)?;
    b.bench("execute_placement_table1_hulk", || {
        execute_placement(&fleet, &tasks, &table1_placement)
    });
    let planet_fleet: fn(u64) -> Fleet = |s| Fleet::synthetic(220, 12, s);
    let planet_workload: fn(&Fleet) -> Vec<ModelSpec> =
        |f| super::sweep::feasible_workload(f, &ModelSpec::paper_six());
    let planet_world =
        ScenarioWorld::for_evaluate(planet_fleet, planet_workload, seed);
    let planet = planet_world.fleet();
    let planet_ctx = planet_world.context(HulkSplitterKind::Oracle);
    let planet_placement = HulkPlanner.plan(&planet_ctx)?;
    let planet_events =
        execute_placement(planet, planet_world.workload(),
                          &planet_placement)
            .report
            .events_processed;
    let r = b.bench("execute_placement_planet_hulk", || {
        execute_placement(planet, planet_world.workload(),
                          &planet_placement)
    });
    let planet_events_per_sec =
        planet_events as f64 / (r.summary.mean / 1e3);
    println!("≈ {planet_events_per_sec:.0} events/sec executing the \
              planet_scale Hulk placement ({planet_events} events)");

    // The evaluation hot path, amortized: what one runner cell costs
    // with the shared per-(scenario, seed) ScenarioWorld (`hit`) vs
    // rebuilding fleet + O(n²) graph + workload from scratch per cell
    // (`miss`, the pre-cache behavior). `world_build_planet` is the
    // miss surcharge on its own.
    b.bench("world_build_planet", || {
        ScenarioWorld::for_evaluate(planet_fleet, planet_workload, seed)
    });
    let system_a_cell = |world: &ScenarioWorld| {
        let ctx = world.context(HulkSplitterKind::Oracle);
        let placement = SystemAPlanner.plan(&ctx).expect("System A plans");
        SystemAPlanner.price(&ctx, &placement)
    };
    b.bench("cell_planet_system_a_miss", || {
        let world = ScenarioWorld::for_evaluate(planet_fleet,
                                                planet_workload, seed);
        system_a_cell(&world)
    });
    b.bench("cell_planet_system_a_hit", || system_a_cell(&planet_world));

    // GCN classification at planet scale: a planet-capable reference
    // artifact (384 slots of headroom over the 220 machines). `dense`
    // is the padded-dense oracle shape — rebuild the graph per call,
    // pad the dense tensors, run the O(slots²·F) forward (the same
    // dense contraction the PJRT artifact's HLO executes); `csr` is
    // the shipped hot path — `ScenarioWorld::classify` over the cached
    // CSR tensors, O(E·F) aggregation, real rows only.
    let clf_cfg = RefGcnConfig { n: 384, f: crate::graph::FEATURE_DIM,
                                 h: 64, h2: 32, c: 8 };
    let clf_params: Vec<f32> = {
        let mut r = Rng::new(seed ^ 0x4743_4E21); // "GCN!"
        (0..clf_cfg.n_params())
            .map(|_| (r.normal() * 0.1) as f32)
            .collect()
    };
    let gcn = RefGcn::new(clf_cfg, &clf_params);
    b.bench("classify_planet_dense", || {
        let graph = ClusterGraph::from_fleet(planet);
        let adj = graph.padded_adj(clf_cfg.n);
        let feats = crate::graph::node_features(&planet.machines, &graph,
                                                clf_cfg.n);
        let mask = graph.padded_mask(clf_cfg.n);
        let probs = gcn.forward(&adj, &feats, &mask);
        (0..planet.len())
            .map(|i| crate::gnn::inference::argmax_class(probs.row(i)))
            .sum::<usize>()
    });
    let clf = crate::gnn::Classifier::Reference(RefGcn::new(clf_cfg,
                                                            &clf_params));
    b.bench("classify_planet_csr", || {
        planet_world.classify(&clf, &clf_params).expect("classify")
    });

    // CSR-first construction (satellite of the hierarchical-graph PR):
    // direct fleet → CSR vs the historical dense-then-compress route.
    // Both emit bit-identical structures (csr.rs tests); the direct path
    // skips the O(n²) intermediate entirely.
    b.bench("csr_from_fleet_planet", || CsrGraph::from_fleet_direct(planet));
    b.bench("csr_via_dense_planet", || {
        CsrGraph::from_graph(&ClusterGraph::from_fleet(planet))
    });

    // Hierarchical graph construction across three fleet decades. The
    // tentpole claim is near-linear growth: ≤~2× per 10× machines once
    // normalized per machine (CI asserts the continent→global step).
    let planet_arc = std::sync::Arc::new(planet.clone());
    b.bench("graph_build_planet", || {
        HierarchicalGraph::from_fleet(planet_arc.clone())
    });
    let continent =
        std::sync::Arc::new(Fleet::synthetic(10_000, 12, seed));
    b.bench("graph_build_continent", || {
        HierarchicalGraph::from_fleet(continent.clone())
    });
    let global =
        std::sync::Arc::new(Fleet::synthetic(100_000, 12, seed));
    b.bench("graph_build_global", || {
        HierarchicalGraph::from_fleet(global.clone())
    });

    // Two-phase region-first planning at scale: coarse region ranking +
    // lazy in-region refinement only (no machine-level n×n anywhere).
    let scale_plan = |fleet: &Fleet, hier: &HierarchicalGraph| {
        let ctx = PlanContext::new(fleet, hier, &tasks,
                                   HulkSplitterKind::Oracle)
            .with_hier(hier);
        HulkPlanner.plan(&ctx).expect("scale plan")
    };
    let continent_hier = HierarchicalGraph::from_fleet(continent.clone());
    b.bench("plan_hulk_continent", || {
        scale_plan(&continent, &continent_hier)
    });
    let global_hier = HierarchicalGraph::from_fleet(global.clone());
    b.bench("plan_hulk_global", || scale_plan(&global, &global_hier));

    // `hulk serve` hot path (serve PR satellite). Two rows:
    // `serve_place_roundtrip_us` — a single Place through a real socket
    // and an in-process daemon (framing + parse + plan + reply);
    // `gcn_forward_batched_8_vs_1x8` — 8 Place requests through ONE
    // shared GnnSplitter forward vs 8 fresh splitters (8 forwards) on
    // the same live world: the batcher's coalescing win as a ratio,
    // asserted < 1 so CI fails if batching ever stops paying.
    use crate::gnn::GnnSplitter;
    use crate::serve::{default_classifier, LiveWorld, PlaceRequest,
                       PlacementCache, ServeConfig, Server};
    // Cache off: this row is the *planning* round-trip lower bound;
    // the cache's own economics get their own rows below.
    let serve_cfg = ServeConfig { seed,
                                  batch_window_ms: 0,
                                  cache_capacity: 0,
                                  ..ServeConfig::default() };
    let server = Server::spawn(&serve_cfg)?;
    let addr = server.addr().expect("tcp daemon has an address");
    let place_req =
        br#"{"op":"place","workload":[{"model":"bert_large","batch":256}]}"#;
    let mut stream = std::net::TcpStream::connect(addr)?;
    let rt = |s: &mut std::net::TcpStream| {
        crate::serve::roundtrip(s, place_req)
            .map_err(|e| anyhow::anyhow!("serve round-trip: {e:?}"))
    };
    rt(&mut stream)?; // warmup: the first request pays the GCN forward
    let iters = 64u32;
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        rt(&mut stream)?;
    }
    let roundtrip_us =
        t0.elapsed().as_secs_f64() * 1e6 / f64::from(iters);
    drop(stream);
    server.stop();
    server.join();
    println!("serve Place round-trip ≈ {roundtrip_us:.0} µs \
              ({iters} iters, batch window 0)");

    let live = LiveWorld::planet(seed, CostBackend::Analytic);
    let (classifier, params) = default_classifier(seed);
    let batch_req = PlaceRequest { workload: tasks.clone(),
                                   systems: vec!["hulk".to_string()] };
    let t0 = std::time::Instant::now();
    let shared = GnnSplitter::new(&classifier, &params);
    for _ in 0..8 {
        std::hint::black_box(live.plan_place(&batch_req, &shared));
    }
    let batched = t0.elapsed().as_secs_f64();
    let t0 = std::time::Instant::now();
    for _ in 0..8 {
        let fresh = GnnSplitter::new(&classifier, &params);
        std::hint::black_box(live.plan_place(&batch_req, &fresh));
    }
    let unbatched = t0.elapsed().as_secs_f64();
    let batched_ratio = batched / unbatched;
    println!("8 batched Place (1 forward) vs 8 unbatched (8 forwards): \
              {:.1} ms vs {:.1} ms ({batched_ratio:.2}x)",
             batched * 1e3, unbatched * 1e3);
    anyhow::ensure!(
        batched_ratio < 1.0,
        "a coalesced batch of 8 must beat 8 sequential forwards \
         (got {batched_ratio:.2}x)");

    // Placement-cache economics on the same live world: a miss plans
    // and stores the reply; a hit returns the stored bytes. Timed
    // steady-state (splitter forward already memoized), i.e. exactly
    // what a shard saves per repeated workload. Asserted hit < miss so
    // CI fails if a lookup ever costs more than planning.
    let scope = live.cache_scope();
    let mut cache = PlacementCache::new(1024);
    let digest = batch_req.digest();
    let t0 = std::time::Instant::now();
    let reply = live.plan_place(&batch_req, &shared);
    cache.insert(scope, digest, &reply);
    let cache_miss_us = t0.elapsed().as_secs_f64() * 1e6;
    let hit_iters = 256u32;
    let t0 = std::time::Instant::now();
    for _ in 0..hit_iters {
        std::hint::black_box(
            cache.get(scope, digest).expect("warmed cache must hit"));
    }
    let cache_hit_us =
        t0.elapsed().as_secs_f64() * 1e6 / f64::from(hit_iters);
    println!("place cache: miss (plan+insert) {cache_miss_us:.0} µs vs \
              hit {cache_hit_us:.1} µs ({hit_iters} iters)");
    anyhow::ensure!(
        cache_hit_us < cache_miss_us,
        "a cache hit ({cache_hit_us:.1} µs) must be cheaper than \
         planning ({cache_miss_us:.0} µs)");

    if cli.flag_bool("json") {
        let out = std::path::PathBuf::from(cli.flag("out").unwrap_or("."));
        let mut report = BenchReport::new("micro");
        report.extend(b.entries("micro"));
        // Simulator throughput trajectory (informational: bigger is
        // better, unlike the ms rows above).
        report.push(BenchEntry::new("micro/sim_planet_events_per_sec",
                                    planet_events_per_sec, "events/s"));
        report.push(BenchEntry::new("micro/sim_planet_events",
                                    planet_events as f64, "count"));
        // Serve hot-path rows (the loadgen-driven serve/* rows live in
        // BENCH_serve.json; these two are daemon-free lower bounds).
        report.push(BenchEntry::new("micro/serve_place_roundtrip_us",
                                    roundtrip_us, "us"));
        report.push(BenchEntry::new("micro/gcn_forward_batched_8_vs_1x8",
                                    batched_ratio, "x"));
        report.push(BenchEntry::new("micro/place_cache_miss_us",
                                    cache_miss_us, "us"));
        report.push(BenchEntry::new("micro/place_cache_hit_us",
                                    cache_hit_us, "us"));
        let path = report.write(&out)?;
        println!("wrote {}", path.display());
    }
    Ok(())
}
