//! Seeded scenario generator + property-testing engine.
//!
//! Turns the planner/simulator stack into a property-testing target:
//! [`generate_case`] derives a randomized `(Fleet, Workload, failure
//! script)` instance from `(seed, index)` — skewed region sizes,
//! heterogeneous GPU mixes, degraded/brownout WAN links, spot
//! revocations — and [`check_case`] runs every registered planner over
//! it, checking the cross-cutting invariants no hand-written scenario
//! pins down exhaustively:
//!
//! - **feasibility** — placements land on live, in-range machines, and
//!   any task priced feasible has a non-empty group with enough
//!   aggregate memory;
//! - **determinism** — planning twice from the same context yields the
//!   same placement (or the same decline);
//! - **self-pricing** — `Placement::cost` agrees entry-for-entry with
//!   the analytic matrix `evaluate_world` reports;
//! - **backend agreement** — the analytic winner's *simulated* cost
//!   stays within a tolerance factor of the simulated winner's;
//! - **oracle bound** — on small (≤ 8-machine) fleets no planner beats
//!   an exhaustive search over every DP/TP/pipeline placement;
//! - **survivor feasibility** — replanning after the failure script's
//!   spot revocations never references a revoked machine.
//!
//! Failures shrink ([`shrink_case`]): the fleet and workload are halved
//! while the violation persists, and the report prints the minimal
//! seed+shape plus the exact CLI command that reproduces it — not a
//! 200-machine dump. The CLI front end is `hulk scenarios generate`;
//! the same engine backs `rust/tests/planner_properties.rs` and the
//! `generated_sweep` benchmark scenario.
//!
//! Everything here is a pure function of `(seed, index)`: no wall
//! clock, no global state, so a printed seed is a complete repro.

use std::collections::BTreeSet;
use std::fmt::{self, Write as _};

use crate::cluster::{Fleet, GpuModel, Machine, Region, WanModel};
use crate::graph::ClusterGraph;
use crate::models::ModelSpec;
use crate::parallel::cost::group_memory_gb;
use crate::parallel::{data_parallel_cost, pipeline_cost,
                      tensor_parallel_cost, IterCost, PipelinePlan};
use crate::planner::{CostBackend, HulkSplitterKind, Placement,
                     PlannerRegistry};
use crate::sim::{sort_script, FailurePlan};
use crate::util::rng::Rng;

use super::evaluate::{evaluate_world, SystemEval};
use super::world::ScenarioWorld;

/// Domain-separation tag mixed into every case seed ("GENCASES").
const GEN_TAG: u64 = 0x4745_4E43_4153_4553;

/// Per-case stream seed: cases of one sweep are mutually independent
/// and case `i` does not depend on how many cases precede it.
fn case_seed(seed: u64, index: usize) -> u64 {
    seed ^ (index as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ GEN_TAG
}

/// The size fingerprint of a generated case — what shrink reports print.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GenShape {
    pub machines: usize,
    pub regions: usize,
    pub tasks: usize,
    pub failures: usize,
}

impl fmt::Display for GenShape {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} machines / {} regions / {} tasks / {} failures",
               self.machines, self.regions, self.tasks, self.failures)
    }
}

/// One generated `(Fleet, Workload, failure script)` instance.
#[derive(Clone, Debug)]
pub struct GenCase {
    /// Sweep seed this case was drawn from.
    pub seed: u64,
    /// Position within the sweep (`generate_case(seed, index)`).
    pub index: usize,
    pub fleet: Fleet,
    pub workload: Vec<ModelSpec>,
    /// Spot revocations, sorted by [`sort_script`]'s canonical order.
    pub failures: Vec<FailurePlan>,
}

impl GenCase {
    pub fn shape(&self) -> GenShape {
        let regions: BTreeSet<Region> =
            self.fleet.machines.iter().map(|m| m.region).collect();
        GenShape {
            machines: self.fleet.len(),
            regions: regions.len(),
            tasks: self.workload.len(),
            failures: self.failures.len(),
        }
    }

    /// The exact CLI invocation that regenerates and re-checks this
    /// case (it is the sweep's last case when `--count index + 1`).
    pub fn repro(&self) -> String {
        format!("hulk scenarios generate --seed {} --count {} --check",
                self.seed, self.index + 1)
    }

    /// The fleet after the failure script's revocations, re-densified
    /// (machine ids must stay `0..len` for `Fleet::new`).
    pub fn survivor_fleet(&self) -> Fleet {
        let dead: BTreeSet<usize> =
            self.failures.iter().map(|f| f.machine).collect();
        let machines: Vec<Machine> = self
            .fleet
            .machines
            .iter()
            .filter(|m| !dead.contains(&m.id))
            .enumerate()
            .map(|(id, m)| Machine::new(id, m.region, m.gpu, m.n_gpus))
            .collect();
        Fleet::new(machines, self.fleet.wan.clone())
    }
}

/// Deterministically generate case `index` of sweep `seed`.
///
/// Shapes are adversarial relative to the hand-written catalog: region
/// populations are skewed (squared-uniform toward the first region),
/// GPU models are mixed per machine, the WAN is randomly degraded
/// (uniform 1.5–6× slowdown) and occasionally loses an inter-region
/// link outright (kept only when the cluster graph stays connected —
/// the planners' documented precondition). ~35% of cases stay at ≤ 8
/// machines so the exhaustive-oracle invariant gets real coverage.
pub fn generate_case(seed: u64, index: usize) -> GenCase {
    let mut rng = Rng::new(case_seed(seed, index));

    // Fleet size: bias toward small instances the oracle can check.
    let n = if rng.chance(0.35) {
        rng.range(4, 8) as usize
    } else {
        rng.range(9, 24) as usize
    };

    // Regions: 2–5 distinct, sorted for id-stable assignment. The
    // Beijing↔Paris pair is policy-blocked in `WanModel`; a fleet
    // holding both would be disconnected by construction, so Paris is
    // swapped for the first unsampled region.
    let n_regions = rng.range(2, (n as i64).min(5)) as usize;
    let mut region_idx = rng.sample_indices(Region::ALL.len(), n_regions);
    region_idx.sort_unstable();
    let mut regions: Vec<Region> =
        region_idx.iter().map(|&i| Region::ALL[i]).collect();
    if regions.contains(&Region::Beijing)
        && regions.contains(&Region::Paris)
    {
        let swap = Region::ALL
            .iter()
            .copied()
            .find(|r| !regions.contains(r))
            .expect("≤5 of 12 regions sampled");
        let pos = regions.iter().position(|&r| r == Region::Paris)
            .expect("contains Paris");
        regions[pos] = swap;
    }

    // Machines: every sampled region gets at least one, the rest are
    // skewed toward region 0 (squared-uniform), with heterogeneous GPU
    // models and counts.
    let mut machines = Vec::with_capacity(n);
    for id in 0..n {
        let region = if id < regions.len() {
            regions[id]
        } else {
            let u = rng.f64() * rng.f64();
            regions[(u * regions.len() as f64) as usize]
        };
        let gpu = *rng.choice(&GpuModel::ALL);
        let n_gpus = *rng.choice(&[4usize, 8, 8, 8, 12]);
        machines.push(Machine::new(id, region, gpu, n_gpus));
    }

    // WAN: fresh latency matrix per case, often degraded (brownout),
    // sometimes with one inter-region link blocked outright — kept
    // only if every machine can still reach every other.
    let mut wan = WanModel::new(rng.next_u64());
    if rng.chance(0.5) {
        wan = wan.scaled(rng.uniform(1.5, 6.0));
    }
    if regions.len() >= 3 && rng.chance(0.25) {
        let pick = rng.sample_indices(regions.len(), 2);
        let blocked =
            wan.with_blocks(&[(regions[pick[0]], regions[pick[1]])]);
        let trial = Fleet::new(machines.clone(), blocked.clone());
        let graph = ClusterGraph::from_fleet(&trial);
        let all: Vec<usize> = (0..trial.len()).collect();
        if graph.subset_connected(&all) {
            wan = blocked;
        }
    }
    let fleet = Fleet::new(machines, wan);

    let workload = sample_workload(&mut rng, fleet.total_memory_gb());

    // Failure script: up to two spot revocations, capped so at least
    // three machines survive (replanning needs a fleet to plan on).
    let max_failures = 2.min(n.saturating_sub(3));
    let count = if max_failures == 0 {
        0
    } else {
        rng.range(0, max_failures as i64) as usize
    };
    let mut failures: Vec<FailurePlan> = rng
        .sample_indices(n, count)
        .into_iter()
        .map(|machine| FailurePlan {
            at_ms: rng.uniform(0.0, 400.0),
            machine,
        })
        .collect();
    sort_script(&mut failures);

    GenCase { seed, index, fleet, workload, failures }
}

/// Draw a seeded workload against an aggregate-memory budget (GB).
///
/// bert_large always participates (it fits the smallest generatable
/// machine, so every planner family has at least one placeable task),
/// plus up to two more catalog models admitted under a 1.6× budget —
/// above Algorithm 1's 1.2× headroom, so declines stay the exception.
/// Batch sizes shrink on some picks to decorrelate draws that picked
/// the same models.
///
/// Extracted from [`generate_case`] so `hulk loadgen` can replay the
/// exact same request mixes against a live daemon; the rng call
/// sequence is part of the generator's determinism contract (the
/// `bench-columns-vs-base` CI gate pins BENCH_scenarios.json
/// byte-for-byte), so any reordering here is a breaking change.
pub fn sample_workload(rng: &mut Rng, budget_gb: f64) -> Vec<ModelSpec> {
    let catalog = [
        ModelSpec::t5_11b(),
        ModelSpec::gpt2_xl(),
        ModelSpec::roberta_large(),
        ModelSpec::xlnet_large(),
        ModelSpec::bert_large(),
    ];
    let mut workload = vec![ModelSpec::bert_large()];
    let mut used = workload[0].train_gb();
    for _ in 0..rng.range(0, 2) {
        let pick = rng.choice(&catalog).clone();
        if (used + pick.train_gb()) * 1.6 <= budget_gb {
            used += pick.train_gb();
            workload.push(pick);
        }
    }
    for m in workload.iter_mut() {
        if rng.chance(0.3) {
            m.batch = (m.batch / 2).max(8);
        }
    }
    workload
}

/// Draw a seeded spot-revocation wave: `count` distinct machines out of
/// `n_machines`, staggered `gap_ms` apart starting at `start_ms` — the
/// same `sample_indices` + canonical-sort machinery [`generate_case`]
/// uses for its failure scripts, packaged for `hulk chaos` to replay
/// against a *live* daemon instead of the simulator. A fresh rng keeps
/// this off [`generate_case`]'s rng-call-order determinism contract.
pub fn sample_failure_wave(rng: &mut Rng, n_machines: usize, count: usize,
                           start_ms: f64, gap_ms: f64) -> Vec<FailurePlan>
{
    let count = count.min(n_machines);
    let mut picks = rng.sample_indices(n_machines, count);
    rng.shuffle(&mut picks);
    let mut wave = crate::sim::staggered_script(&picks, start_ms, gap_ms);
    sort_script(&mut wave);
    wave
}

/// Tunables for [`check_case`].
#[derive(Clone, Copy, Debug)]
pub struct CheckOptions {
    /// Backend agreement: the analytic winner's simulated cost may
    /// exceed the simulated winner's by at most this factor. Loose by
    /// design — shared-link contention (absent from the analytic
    /// model) and System B's serialized-transfer overestimate can
    /// legitimately re-rank close placements; the invariant guards
    /// against order-of-magnitude divergence, i.e. a planner whose
    /// self-reported costs are fiction.
    pub winner_tolerance: f64,
    /// Run the exhaustive placement oracle on fleets up to this size
    /// (the search is over every ordered subset; 8 machines ≈ 10⁵
    /// permutations per task, 9 is the hard ceiling).
    pub oracle_max_machines: usize,
}

impl Default for CheckOptions {
    fn default() -> Self {
        CheckOptions { winner_tolerance: 10.0, oracle_max_machines: 8 }
    }
}

/// One invariant violation; `planner` is a slug, `"(all)"` for
/// cross-planner invariants or `"(generator)"` for generator bugs.
#[derive(Clone, Debug)]
pub struct Violation {
    pub invariant: &'static str,
    pub planner: &'static str,
    pub detail: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[{}] {}: {}", self.invariant, self.planner,
               self.detail)
    }
}

/// What [`check_case`] found for one case.
#[derive(Clone, Debug, Default)]
pub struct CaseReport {
    pub violations: Vec<Violation>,
    /// Every registered planner produced a placement (none declined);
    /// only such cases exercise the pricing/backends/oracle checks.
    pub fully_planned: bool,
}

fn costs_close(a: IterCost, b: IterCost) -> bool {
    match (a.is_feasible(), b.is_feasible()) {
        (false, false) => true,
        (true, true) => {
            let rel = |x: f64, y: f64| {
                (x - y).abs() <= 1e-9 * x.abs().max(y.abs()).max(1.0)
            };
            rel(a.comm_ms, b.comm_ms) && rel(a.comp_ms, b.comp_ms)
        }
        _ => false,
    }
}

/// Cheapest feasible column for a task, `None` if every system is
/// infeasible on it. Mirrors the winner rule the scenarios report.
fn winner(eval: &SystemEval, task: usize) -> Option<usize> {
    (0..eval.systems.len())
        .filter(|&s| eval.costs[task][s].total_ms().is_finite())
        .min_by(|&x, &y| {
            eval.costs[task][x]
                .total_ms()
                .total_cmp(&eval.costs[task][y].total_ms())
        })
}

/// Heap's algorithm: visit every permutation of `xs` in place.
fn heap_permutations(xs: &mut [usize],
                     visit: &mut impl FnMut(&[usize]))
{
    fn go(k: usize, xs: &mut [usize],
          visit: &mut impl FnMut(&[usize]))
    {
        if k <= 1 {
            visit(xs);
            return;
        }
        for i in 0..k {
            go(k - 1, xs, visit);
            if k % 2 == 0 {
                xs.swap(i, k - 1);
            } else {
                xs.swap(0, k - 1);
            }
        }
    }
    go(xs.len(), xs, visit);
}

/// Brute-force placement oracle: the cheapest analytic cost of `model`
/// over every placement family any planner can emit — data-parallel,
/// tensor-parallel and proportional pipelines over every *ordered*
/// non-empty machine subset (ring and chain costs are order-
/// sensitive, so id-order subsets alone would not bound System C's
/// grouping or Hulk's latency-sorted chains).
pub fn exhaustive_best(fleet: &Fleet, model: &ModelSpec) -> IterCost {
    let n = fleet.len();
    assert!(n <= 9, "exhaustive oracle explodes past 9 machines");
    let mut best = IterCost::infeasible();
    for mask in 1u32..(1 << n) {
        let subset: Vec<usize> =
            (0..n).filter(|&i| (mask >> i) & 1 == 1).collect();
        let mut perm = subset.clone();
        heap_permutations(&mut perm, &mut |order: &[usize]| {
            let dp = data_parallel_cost(fleet, order, model);
            if dp.total_ms() < best.total_ms() {
                best = dp;
            }
            let tp = tensor_parallel_cost(fleet, order, model);
            if tp.total_ms() < best.total_ms() {
                best = tp;
            }
            if order.len() <= model.layers {
                let plan = PipelinePlan::proportional(
                    fleet, order.to_vec(), model);
                let pl = pipeline_cost(fleet, &plan, model);
                if pl.total_ms() < best.total_ms() {
                    best = pl;
                }
            }
        });
    }
    best
}

/// Run every planner in `planners` over `case` and check the
/// cross-cutting invariants (module docs list them). Declining to plan
/// (an `Err` from `plan`, e.g. Algorithm 1 deferring an oversized
/// task) is not a violation as long as it is deterministic; cases with
/// any decline skip the pricing-dependent phases and report
/// `fully_planned: false`.
pub fn check_case(case: &GenCase, planners: &PlannerRegistry,
                  opts: &CheckOptions) -> CaseReport
{
    let mut v: Vec<Violation> = Vec::new();
    let world =
        ScenarioWorld::new(case.fleet.clone(), case.workload.clone());
    let ctx = world.context(HulkSplitterKind::Oracle);

    // Phase 1: per-planner determinism + structural feasibility.
    let mut planned: Vec<Option<Placement>> = Vec::new();
    let mut structural = false;
    for planner in planners.iter() {
        let first = planner.plan(&ctx);
        let second = planner.plan(&ctx);
        match (&first, &second) {
            (Ok(a), Ok(b)) if a != b => v.push(Violation {
                invariant: "determinism",
                planner: planner.slug(),
                detail: "same context, different placements across two \
                         plan() calls"
                    .into(),
            }),
            (Ok(_), Err(e)) | (Err(e), Ok(_)) => v.push(Violation {
                invariant: "determinism",
                planner: planner.slug(),
                detail: format!(
                    "plans on one run, declines on the other ({e})"),
            }),
            (Err(a), Err(b)) if a.to_string() != b.to_string() => {
                v.push(Violation {
                    invariant: "determinism",
                    planner: planner.slug(),
                    detail: format!("declines differently: {a} vs {b}"),
                })
            }
            _ => {}
        }
        match first {
            Ok(p) => {
                if let Err(e) = p.validate_machines(world.fleet()) {
                    v.push(Violation {
                        invariant: "feasibility",
                        planner: planner.slug(),
                        detail: e,
                    });
                    structural = true;
                    planned.push(None);
                } else {
                    for (t, model) in
                        world.workload().iter().enumerate()
                    {
                        let cost = p.cost(world.fleet(), model, t);
                        if !cost.is_feasible() {
                            continue;
                        }
                        let group = p.machines(t);
                        if group.is_empty()
                            || group_memory_gb(world.fleet(), group)
                                + 1e-9
                                < model.train_gb()
                        {
                            v.push(Violation {
                                invariant: "capacity",
                                planner: planner.slug(),
                                detail: format!(
                                    "task {t} ({}) priced feasible on \
                                     group {group:?} with {:.1} GB < \
                                     {:.1} GB needed",
                                    model.name,
                                    group_memory_gb(
                                        world.fleet(), group),
                                    model.train_gb()),
                            });
                        }
                    }
                    planned.push(Some(p));
                }
            }
            Err(_) => planned.push(None),
        }
    }
    if structural {
        // Out-of-range machine ids make any pricing below unsafe
        // (`Placement::cost` indexes the fleet) — report what we have.
        return CaseReport { violations: v, fully_planned: false };
    }

    let fully_planned = planned.iter().all(|p| p.is_some());
    if fully_planned {
        match evaluate_world(planners, &world, HulkSplitterKind::Oracle,
                             CostBackend::Analytic)
        {
            Err(e) => v.push(Violation {
                invariant: "determinism",
                planner: "(all)",
                detail: format!(
                    "every planner planned individually, but \
                     evaluate_world failed: {e}"),
            }),
            Ok(analytic) => {
                // Self-pricing: Placement::cost must reproduce the
                // analytic matrix entry for entry.
                for (s, (planner, p)) in
                    planners.iter().zip(&planned).enumerate()
                {
                    let p = p.as_ref().expect("fully planned");
                    for (t, model) in
                        world.workload().iter().enumerate()
                    {
                        let own = p.cost(world.fleet(), model, t);
                        let evaled = analytic.costs[t][s];
                        if !costs_close(own, evaled) {
                            v.push(Violation {
                                invariant: "self-pricing",
                                planner: planner.slug(),
                                detail: format!(
                                    "task {t} ({}): self-priced \
                                     {:.3}ms vs evaluate_world \
                                     {:.3}ms",
                                    model.name,
                                    own.total_ms(),
                                    evaled.total_ms()),
                            });
                        }
                    }
                }
                // Backend agreement on the per-task winner.
                match evaluate_world(planners, &world,
                                     HulkSplitterKind::Oracle,
                                     CostBackend::Simulated)
                {
                    Err(e) => v.push(Violation {
                        invariant: "determinism",
                        planner: "(all)",
                        detail: format!(
                            "analytic evaluation succeeded but the \
                             simulated one failed: {e}"),
                    }),
                    Ok(sim) => {
                        for (t, model) in
                            world.workload().iter().enumerate()
                        {
                            let (Some(wa), Some(ws)) =
                                (winner(&analytic, t), winner(&sim, t))
                            else {
                                continue;
                            };
                            let sim_of = |s: usize| {
                                sim.costs[t][s].total_ms()
                            };
                            if sim_of(wa).is_finite()
                                && sim_of(ws).is_finite()
                                && sim_of(wa)
                                    > sim_of(ws)
                                        * opts.winner_tolerance
                            {
                                v.push(Violation {
                                    invariant: "backend-agreement",
                                    planner: "(all)",
                                    detail: format!(
                                        "task {t} ({}): analytic \
                                         winner {} simulates at \
                                         {:.1}ms, over {}× the sim \
                                         winner {}'s {:.1}ms",
                                        model.name,
                                        analytic.systems[wa].slug,
                                        sim_of(wa),
                                        opts.winner_tolerance,
                                        sim.systems[ws].slug,
                                        sim_of(ws)),
                                });
                            }
                        }
                    }
                }
                // Oracle bound on small fleets.
                if world.fleet().len() <= opts.oracle_max_machines {
                    for (t, model) in
                        world.workload().iter().enumerate()
                    {
                        let best = exhaustive_best(world.fleet(),
                                                   model)
                            .total_ms();
                        for (s, planner) in
                            planners.iter().enumerate()
                        {
                            let c = analytic.costs[t][s].total_ms();
                            if c.is_finite()
                                && c < best * (1.0 - 1e-9) - 1e-6
                            {
                                v.push(Violation {
                                    invariant: "oracle-bound",
                                    planner: planner.slug(),
                                    detail: format!(
                                        "task {t} ({}): priced \
                                         {c:.3}ms, below the \
                                         exhaustive optimum \
                                         {best:.3}ms",
                                        model.name),
                                });
                            }
                        }
                    }
                }
            }
        }
    }

    // Survivor feasibility: after the failure script's revocations,
    // replanning must never reference a revoked machine. Survivor ids
    // are re-densified, so in-range means alive.
    if !case.failures.is_empty() {
        let sworld = ScenarioWorld::new(case.survivor_fleet(),
                                        case.workload.clone());
        let sctx = sworld.context(HulkSplitterKind::Oracle);
        for planner in planners.iter() {
            if let Ok(p) = planner.plan(&sctx) {
                if let Err(e) = p.validate_machines(sworld.fleet()) {
                    v.push(Violation {
                        invariant: "survivor-feasibility",
                        planner: planner.slug(),
                        detail: format!(
                            "after revoking {:?}: {e}",
                            case.failures
                                .iter()
                                .map(|f| f.machine)
                                .collect::<Vec<_>>()),
                    });
                }
            }
        }
    }

    CaseReport { violations: v, fully_planned }
}

/// The generator's own invariant: regenerating `(seed, index)` must
/// reproduce the case bit-for-bit. Checked separately from
/// [`check_case`] because shrunk cases are intentionally *not*
/// regenerable (they are truncations, not draws).
pub fn check_generator_determinism(case: &GenCase) -> Option<Violation> {
    let again = generate_case(case.seed, case.index);
    let same = again.fleet.machines == case.fleet.machines
        && wan_probe(&again.fleet) == wan_probe(&case.fleet)
        && again.workload == case.workload
        && again.failures == case.failures;
    if same {
        None
    } else {
        Some(Violation {
            invariant: "generator-determinism",
            planner: "(generator)",
            detail: format!(
                "case {} regenerated differently from seed {}",
                case.index, case.seed),
        })
    }
}

/// Latency fingerprint of the fleet's WAN (bit-exact, covers scaling
/// and blocks) — `WanModel` itself has no equality.
fn wan_probe(fleet: &Fleet) -> Vec<Option<u64>> {
    let mut probes = Vec::new();
    for a in &fleet.machines {
        for b in &fleet.machines {
            probes.push(fleet
                .wan
                .latency_ms(a.region, b.region)
                .map(f64::to_bits));
        }
    }
    probes
}

fn halve_fleet(case: &GenCase) -> Option<GenCase> {
    let n = case.fleet.len();
    if n <= 2 {
        return None;
    }
    let keep = n.div_ceil(2);
    let machines: Vec<Machine> = case.fleet.machines[..keep]
        .iter()
        .enumerate()
        .map(|(id, m)| Machine::new(id, m.region, m.gpu, m.n_gpus))
        .collect();
    let mut failures: Vec<FailurePlan> = case
        .failures
        .iter()
        .copied()
        .filter(|f| f.machine < keep)
        .collect();
    while keep - failures.len() < 2 {
        failures.pop();
    }
    Some(GenCase {
        seed: case.seed,
        index: case.index,
        fleet: Fleet::new(machines, case.fleet.wan.clone()),
        workload: case.workload.clone(),
        failures,
    })
}

fn halve_workload(case: &GenCase) -> Option<GenCase> {
    if case.workload.len() <= 1 {
        return None;
    }
    let keep = case.workload.len().div_ceil(2);
    Some(GenCase {
        seed: case.seed,
        index: case.index,
        fleet: case.fleet.clone(),
        workload: case.workload[..keep].to_vec(),
        failures: case.failures.clone(),
    })
}

/// Shrink a failing case: repeatedly halve the fleet, then the
/// workload, keeping a candidate whenever *some* invariant still
/// fails, until no halving reproduces a violation. Returns the minimal
/// failing case and its violations (the input's, if it cannot shrink;
/// empty if the input did not fail at all).
pub fn shrink_case(case: &GenCase, planners: &PlannerRegistry,
                   opts: &CheckOptions) -> (GenCase, Vec<Violation>)
{
    let mut current = case.clone();
    let mut violations = check_case(&current, planners, opts).violations;
    loop {
        let mut shrunk = false;
        for candidate in
            [halve_fleet(&current), halve_workload(&current)]
                .into_iter()
                .flatten()
        {
            let report = check_case(&candidate, planners, opts);
            if !report.violations.is_empty() {
                violations = report.violations;
                current = candidate;
                shrunk = true;
                break;
            }
        }
        if !shrunk {
            break;
        }
    }
    (current, violations)
}

/// Human-readable failure report: violations, original vs shrunk
/// shape, and the exact command that reproduces the case.
pub fn shrink_report(original: &GenCase, minimal: &GenCase,
                     violations: &[Violation]) -> String
{
    let mut out = String::new();
    let _ = writeln!(
        out,
        "property violation in generated case {} of seed {}:",
        original.index, original.seed);
    for v in violations {
        let _ = writeln!(out, "  - {v}");
    }
    let _ = writeln!(out, "  original shape: {}", original.shape());
    if minimal.shape() != original.shape() {
        let _ = writeln!(out, "  shrunk to:      {}", minimal.shape());
    }
    let _ = writeln!(out, "  reproduce with: {}", original.repro());
    out
}

/// Aggregate outcome of a `--check` sweep.
#[derive(Clone, Debug)]
pub struct GeneratedRun {
    /// Cases generated and checked (stops at the first failure).
    pub cases: usize,
    /// Cases every planner fully planned (pricing phases exercised).
    pub fully_planned: usize,
    /// Total violations found (0 on a clean sweep).
    pub violations: usize,
    /// Shrunk repro report for the first failing case.
    pub failure: Option<String>,
}

/// Generate `count` cases from `seed` and check each (generator
/// determinism + [`check_case`]); on the first failing case, shrink it
/// and stop. Pure in `(seed, count, planners, opts)`.
pub fn run_generated(seed: u64, count: usize,
                     planners: &PlannerRegistry, opts: &CheckOptions)
    -> GeneratedRun
{
    let mut run = GeneratedRun {
        cases: 0,
        fully_planned: 0,
        violations: 0,
        failure: None,
    };
    for index in 0..count {
        let case = generate_case(seed, index);
        run.cases += 1;
        let mut report = check_case(&case, planners, opts);
        if let Some(gen_v) = check_generator_determinism(&case) {
            report.violations.push(gen_v);
        }
        if report.fully_planned {
            run.fully_planned += 1;
        }
        if !report.violations.is_empty() {
            run.violations += report.violations.len();
            let (minimal, min_v) = shrink_case(&case, planners, opts);
            // A generator-determinism violation on an otherwise-clean
            // case leaves shrink_case nothing to reproduce; fall back
            // to the original violation list.
            let vs = if min_v.is_empty() {
                report.violations.clone()
            } else {
                min_v
            };
            run.failure = Some(shrink_report(&case, &minimal, &vs));
            break;
        }
    }
    run
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallel::data_parallel::replica_capable;

    #[test]
    fn generation_is_deterministic_and_in_bounds() {
        for index in 0..30 {
            let case = generate_case(11, index);
            let shape = case.shape();
            assert!((4..=24).contains(&shape.machines), "{shape}");
            assert!((2..=5).contains(&shape.regions), "{shape}");
            assert!((1..=3).contains(&shape.tasks), "{shape}");
            assert!(shape.failures <= 2, "{shape}");
            assert!(check_generator_determinism(&case).is_none());
            for (i, m) in case.fleet.machines.iter().enumerate() {
                assert_eq!(m.id, i);
            }
            for f in &case.failures {
                assert!(f.machine < case.fleet.len());
                assert!(f.at_ms >= 0.0);
            }
            // The cluster graph must stay connected — the planners'
            // documented precondition — even when a WAN link was
            // blocked or Beijing/Paris were both drawn.
            let graph = ClusterGraph::from_fleet(&case.fleet);
            let all: Vec<usize> = (0..case.fleet.len()).collect();
            assert!(graph.subset_connected(&all),
                    "case {index} disconnected");
            let regions: Vec<Region> = case
                .fleet
                .machines
                .iter()
                .map(|m| m.region)
                .collect();
            assert!(!(regions.contains(&Region::Beijing)
                      && regions.contains(&Region::Paris)),
                    "policy-blocked region pair generated");
            assert!(case.survivor_fleet().len() >= 2);
        }
    }

    #[test]
    fn failure_wave_is_seeded_distinct_and_canonical() {
        let wave = sample_failure_wave(&mut Rng::new(7), 220, 12,
                                       100.0, 40.0);
        assert_eq!(wave.len(), 12);
        let mut ids: Vec<usize> =
            wave.iter().map(|f| f.machine).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 12, "revoked machines must be distinct");
        assert!(wave.iter().all(|f| f.machine < 220));
        // Canonically ordered and staggered at the requested cadence.
        for (k, f) in wave.iter().enumerate() {
            assert_eq!(f.at_ms, 100.0 + k as f64 * 40.0);
        }
        // Pure function of the seed.
        assert_eq!(wave, sample_failure_wave(&mut Rng::new(7), 220, 12,
                                             100.0, 40.0));
        // Count is clamped to the fleet.
        assert_eq!(sample_failure_wave(&mut Rng::new(1), 3, 9, 0.0, 1.0)
                       .len(),
                   3);
    }

    #[test]
    fn different_seeds_give_different_cases() {
        let a = generate_case(1, 0);
        let b = generate_case(2, 0);
        assert!(a.fleet.machines != b.fleet.machines
                || a.workload != b.workload
                || a.failures != b.failures);
    }

    #[test]
    fn exhaustive_oracle_lower_bounds_hand_built_placements() {
        let fleet = Fleet::paper_toy(0);
        let model = ModelSpec::bert_large();
        let best = exhaustive_best(&fleet, &model);
        assert!(best.is_feasible());
        let dp = data_parallel_cost(
            &fleet, &replica_capable(&fleet, &model), &model);
        assert!(best.total_ms() <= dp.total_ms() + 1e-6);
        let pipe =
            PipelinePlan::proportional(&fleet, vec![0, 1, 2], &model);
        let pl = pipeline_cost(&fleet, &pipe, &model);
        if pl.is_feasible() {
            assert!(best.total_ms() <= pl.total_ms() + 1e-6);
        }
    }

    #[test]
    fn halving_keeps_cases_well_formed() {
        let case = generate_case(3, 0);
        let halved = halve_fleet(&case).expect("≥4 machines halve");
        assert_eq!(halved.fleet.len(), case.fleet.len().div_ceil(2));
        for (i, m) in halved.fleet.machines.iter().enumerate() {
            assert_eq!(m.id, i);
        }
        assert!(halved
            .failures
            .iter()
            .all(|f| f.machine < halved.fleet.len()));
        assert!(halved.fleet.len() - halved.failures.len() >= 2);
        let two_tasks = GenCase {
            workload: vec![ModelSpec::bert_large(),
                           ModelSpec::gpt2_xl()],
            ..case.clone()
        };
        let smaller =
            halve_workload(&two_tasks).expect("2 tasks halve");
        assert_eq!(smaller.workload.len(), 1);
        assert!(halve_workload(&smaller).is_none());
    }

    #[test]
    fn checks_pass_on_a_handful_of_cases() {
        let planners = PlannerRegistry::standard();
        let opts = CheckOptions::default();
        let mut planned = 0;
        for index in 0..4 {
            let case = generate_case(5, index);
            let report = check_case(&case, &planners, &opts);
            assert!(report.violations.is_empty(),
                    "case {index}: {:?}", report.violations);
            planned += usize::from(report.fully_planned);
        }
        assert!(planned >= 1, "no case fully planned");
    }

    #[test]
    fn repro_command_names_seed_and_count() {
        let case = generate_case(9, 4);
        assert_eq!(case.repro(),
                   "hulk scenarios generate --seed 9 --count 5 --check");
    }
}
