//! [`ScenarioWorld`] — the per-(scenario, seed) context cache.
//!
//! Every (scenario × planner × seed × backend) cell used to rebuild the
//! same fleet, re-derive the O(n²) [`ClusterGraph`], and re-sort the
//! workload from scratch; at planet scale that rebuild dominated the
//! whole evaluation loop. A `ScenarioWorld` is built **once** per
//! (scenario, seed) and shared — the runner hands one `Arc` to every
//! cell of a spec (`--parallel` workers share the same allocation, they
//! do not clone it), `evaluate` consumes it directly, and custom
//! scenario bodies reuse one world across their evaluation + DES steps.
//!
//! Everything inside is a pure function of `(fleet builder, workload
//! builder, effective seed)`, so sharing cannot change any artifact
//! byte: the runner's cache-off mode rebuilds a fresh world per cell
//! and CI asserts the outputs are identical
//! (`rust/tests/world_cache.rs`).
//!
//! Ownership (see DESIGN.md §ScenarioWorld for the full diagram):
//!
//! ```text
//! ScenarioWorld (Arc, one per scenario × seed)
//! ├── fleet:    Arc<Fleet>          built once from the effective seed
//! ├── graph:    Arc<ClusterGraph>   O(n²) adjacency, built once
//! ├── workload: Vec<ModelSpec>      canonical (largest-first) order
//! └── padded:   Arc<Mutex<…>>       lazily, per artifact slot count:
//!     └── PaddedWorld { csr, feats, mask }   GCN inference tensors
//! ```
//!
//! `with_workload` forks a world that shares the fleet/graph/padded
//! arcs — how `failure_storm` sheds oversized tasks without paying a
//! graph rebuild per retry.

use std::sync::{Arc, Mutex, OnceLock};

use anyhow::Result;

use crate::cluster::Fleet;
use crate::gnn::Classifier;
use crate::graph::{node_features_csr, ClusterGraph, CsrGraph};
use crate::models::ModelSpec;
use crate::planner::{HulkSplitterKind, PlanContext};

/// Padded GCN-inference tensors for one artifact slot count: the CSR
/// adjacency view plus features and node mask, all shaped `[slots, …]`.
/// The dense `slots²` adjacency (what the PJRT artifact and the dense
/// oracle consume) is materialized lazily — backends on the CSR path
/// never pay for it.
#[derive(Debug)]
pub struct PaddedWorld {
    pub slots: usize,
    pub csr: CsrGraph,
    pub feats: Vec<f32>,
    pub mask: Vec<f32>,
    dense: OnceLock<Vec<f32>>,
}

impl PaddedWorld {
    /// The dense padded adjacency, built from the CSR view on first use
    /// and cached (identical to `ClusterGraph::padded_adj`).
    pub fn dense_adj(&self) -> &[f32] {
        self.dense.get_or_init(|| self.csr.to_dense())
    }
}

/// The shared per-(scenario, seed) arena. See the module docs.
#[derive(Clone, Debug)]
pub struct ScenarioWorld {
    fleet: Arc<Fleet>,
    graph: Arc<ClusterGraph>,
    workload: Vec<ModelSpec>,
    /// Lazily built padded tensors, keyed by slot count (tiny: one or
    /// two artifact sizes per process). Shared across
    /// `with_workload` forks.
    padded: Arc<Mutex<Vec<Arc<PaddedWorld>>>>,
}

impl ScenarioWorld {
    /// Build a world from parts: sorts `workload` into canonical
    /// (largest-first) order and derives the cluster graph once.
    pub fn new(fleet: Fleet, mut workload: Vec<ModelSpec>)
        -> ScenarioWorld
    {
        ModelSpec::sort_largest_first(&mut workload);
        let graph = ClusterGraph::from_fleet(&fleet);
        ScenarioWorld {
            fleet: Arc::new(fleet),
            graph: Arc::new(graph),
            workload,
            padded: Arc::new(Mutex::new(Vec::new())),
        }
    }

    /// The world of an `Evaluate` scenario body: fleet from the
    /// effective seed, workload on that fleet, canonical order.
    pub fn for_evaluate(fleet: fn(u64) -> Fleet,
                        workload: fn(&Fleet) -> Vec<ModelSpec>,
                        eff_seed: u64) -> ScenarioWorld
    {
        let fl = fleet(eff_seed);
        let wl = workload(&fl);
        ScenarioWorld::new(fl, wl)
    }

    pub fn fleet(&self) -> &Fleet {
        &self.fleet
    }

    pub fn graph(&self) -> &ClusterGraph {
        &self.graph
    }

    /// The workload in canonical (largest-first) order.
    pub fn workload(&self) -> &[ModelSpec] {
        &self.workload
    }

    /// A fork with a different workload that **shares** the fleet,
    /// graph, and padded-tensor caches (cheap: three `Arc` clones plus
    /// the sort).
    pub fn with_workload(&self, mut workload: Vec<ModelSpec>)
        -> ScenarioWorld
    {
        ModelSpec::sort_largest_first(&mut workload);
        ScenarioWorld {
            fleet: self.fleet.clone(),
            graph: self.graph.clone(),
            workload,
            padded: self.padded.clone(),
        }
    }

    /// A [`PlanContext`] borrowing this world — the seam every planner
    /// and both cost backends consume. Analytic backend by default;
    /// chain [`PlanContext::with_backend`] to switch.
    pub fn context(&self, splitter: HulkSplitterKind<'_>)
        -> PlanContext<'_>
    {
        PlanContext::new(&self.fleet, &self.graph, &self.workload,
                         splitter)
    }

    /// Classify every machine through the **cached** padded tensors —
    /// the amortized counterpart of [`crate::gnn::classify`]: the CSR
    /// view, features, mask (and, for dense-path backends like the
    /// PJRT artifact, the dense adjacency) are built once per (world,
    /// slot count) and every subsequent call is pure forward + argmax.
    pub fn classify(&self, classifier: &Classifier, params: &[f32])
        -> Result<Vec<usize>>
    {
        let padded = self.padded(classifier.slots());
        let probs = if classifier.uses_csr(&padded.csr) {
            classifier.probs_for_padded(params, &padded.csr,
                                        &padded.feats, &padded.mask)?
        } else {
            // Dense-path backend: feed the cached dense tensor instead
            // of letting `probs_for_padded` re-materialize it per call.
            classifier.probs(params, padded.dense_adj(), &padded.feats,
                             &padded.mask)?
        };
        Ok(crate::gnn::inference::classes_from_probs(
            &probs, self.fleet.len(), classifier.n_classes()))
    }

    /// The padded GCN tensors for `slots` artifact slots, built on
    /// first use and cached (thread-safe; `--parallel` cells share the
    /// same build).
    pub fn padded(&self, slots: usize) -> Arc<PaddedWorld> {
        let mut cache = self.padded.lock().expect("padded cache poisoned");
        if let Some(hit) = cache.iter().find(|p| p.slots == slots) {
            return hit.clone();
        }
        let csr = CsrGraph::padded(&self.graph, slots);
        let feats = node_features_csr(&self.fleet.machines, &csr);
        let mask = self.graph.padded_mask(slots);
        let built = Arc::new(PaddedWorld { slots, csr, feats, mask,
                                           dense: OnceLock::new() });
        cache.push(built.clone());
        built
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::node_features;

    #[test]
    fn world_canonicalizes_the_workload() {
        let world = ScenarioWorld::new(Fleet::paper_evaluation(0),
                                       ModelSpec::paper_six());
        assert!(crate::planner::is_canonical(world.workload()));
        assert_eq!(world.graph().n, world.fleet().len());
    }

    #[test]
    fn padded_tensors_match_the_from_scratch_build() {
        let world = ScenarioWorld::new(Fleet::paper_evaluation(0),
                                       ModelSpec::paper_four());
        let slots = world.fleet().len() + 18;
        let padded = world.padded(slots);
        assert_eq!(padded.feats,
                   node_features(&world.fleet().machines, world.graph(),
                                 slots));
        assert_eq!(padded.mask, world.graph().padded_mask(slots));
        assert_eq!(padded.csr, CsrGraph::padded(world.graph(), slots));
        assert_eq!(padded.dense_adj(), world.graph().padded_adj(slots));
        // Second request is the cached allocation, not a rebuild.
        let again = world.padded(slots);
        assert!(Arc::ptr_eq(&padded, &again));
        // A different slot count coexists.
        let other = world.padded(slots + 4);
        assert_eq!(other.slots, slots + 4);
    }

    #[test]
    fn cached_classify_matches_the_from_scratch_path() {
        use crate::gnn::{classify, RefGcn, RefGcnConfig};
        use crate::util::rng::Rng;
        let world = ScenarioWorld::new(Fleet::paper_evaluation(0),
                                       ModelSpec::paper_four());
        let cfg = RefGcnConfig { n: 64, f: crate::graph::FEATURE_DIM,
                                 h: 16, h2: 8, c: 8 };
        let mut rng = Rng::new(23);
        let params: Vec<f32> = (0..cfg.n_params())
            .map(|_| (rng.normal() * 0.1) as f32)
            .collect();
        let clf = Classifier::Reference(RefGcn::new(cfg, &params));
        let cached = world.classify(&clf, &params).unwrap();
        assert_eq!(cached,
                   classify(&clf, &params, world.fleet()).unwrap());
        // The call populated the padded cache for the artifact size.
        assert_eq!(world.padded(64).slots, 64);
    }

    #[test]
    fn workload_fork_shares_fleet_graph_and_padded_cache() {
        let world = ScenarioWorld::new(Fleet::paper_evaluation(0),
                                       ModelSpec::paper_four());
        let padded = world.padded(64);
        let fork = world.with_workload(vec![ModelSpec::bert_large()]);
        assert_eq!(fork.workload().len(), 1);
        assert!(std::ptr::eq(world.fleet(), fork.fleet()));
        assert!(std::ptr::eq(world.graph(), fork.graph()));
        assert!(Arc::ptr_eq(&padded, &fork.padded(64)));
    }

    #[test]
    fn fork_workload_mutation_never_invalidates_parent_tensors() {
        // The failure_storm shed-loop shape: repeated forks with
        // mutated workloads must leave the parent's cached GCN tensors
        // bit-identical and its workload untouched — the padded cache
        // is keyed by slot count only, never by workload.
        let world = ScenarioWorld::new(Fleet::paper_evaluation(0),
                                       ModelSpec::paper_four());
        let padded = world.padded(64);
        let feats_before = padded.feats.clone();
        let mask_before = padded.mask.clone();
        let dense_before = padded.dense_adj().to_vec();
        let parent_workload = world.workload().to_vec();
        let mut wl = ModelSpec::paper_six();
        for _ in 0..3 {
            wl.pop();
            let mut small = ModelSpec::bert_large();
            small.batch /= 2;
            wl.push(small);
            let fork = world.with_workload(wl.clone());
            assert!(std::ptr::eq(world.graph(), fork.graph()),
                    "fork must share the Arc'd graph");
            assert!(Arc::ptr_eq(&padded, &fork.padded(64)));
            // A fork growing the shared cache with a new slot count is
            // additive, never an invalidation.
            assert_eq!(fork.padded(96).slots, 96);
        }
        assert_eq!(world.workload(), &parent_workload[..]);
        let after = world.padded(64);
        assert!(Arc::ptr_eq(&padded, &after));
        assert_eq!(after.feats, feats_before);
        assert_eq!(after.mask, mask_before);
        assert_eq!(after.dense_adj(), &dense_before[..]);
        // The slot count a fork built is visible to the parent — one
        // shared cache, not a copy-on-write.
        assert_eq!(world.padded(96).slots, 96);
    }

    #[test]
    fn context_borrows_the_world() {
        let world = ScenarioWorld::new(Fleet::paper_evaluation(0),
                                       ModelSpec::paper_four());
        let ctx = world.context(HulkSplitterKind::Oracle);
        assert_eq!(ctx.workload.len(), 4);
        assert!(std::ptr::eq(ctx.fleet, world.fleet()));
        assert!(std::ptr::eq(ctx.graph, world.graph()));
    }
}
