//! [`ScenarioWorld`] — the per-(scenario, seed) context cache.
//!
//! Every (scenario × planner × seed × backend) cell used to rebuild the
//! same fleet, re-derive the cluster graph, and re-sort the workload
//! from scratch; at planet scale that rebuild dominated the whole
//! evaluation loop. A `ScenarioWorld` is built **once** per
//! (scenario, seed) and shared — the runner hands one `Arc` to every
//! cell of a spec (`--parallel` workers share the same allocation, they
//! do not clone it), `evaluate` consumes it directly, and custom
//! scenario bodies reuse one world across their evaluation + DES steps.
//!
//! The planning substrate is a [`HierarchicalGraph`] built directly
//! from the fleet — no dense n×n adjacency on the construction path.
//! For fleets at or under [`crate::graph::HIER_THRESHOLD`] its fine
//! level is a full CSR whose weights are bit-identical to the dense
//! oracle's, so every historical artifact byte is preserved; past the
//! threshold the fine level stays lazy and Hulk-family planners go
//! region-first ([`PlanContext::hier`]).
//!
//! Everything inside is a pure function of `(fleet builder, workload
//! builder, effective seed)`, so sharing cannot change any artifact
//! byte: the runner's cache-off mode rebuilds a fresh world per cell
//! and CI asserts the outputs are identical
//! (`rust/tests/world_cache.rs`), and the dense-oracle mode
//! ([`ScenarioWorld::new_dense_oracle`]) re-plans everything on the
//! demoted dense [`ClusterGraph`] so `rust/tests/hier_parity.rs` can
//! assert the hierarchical substrate changes nothing either.
//!
//! Ownership (see DESIGN.md §ScenarioWorld for the full diagram):
//!
//! ```text
//! ScenarioWorld (Arc, one per scenario × seed)
//! ├── fleet:    Arc<Fleet>               built once from the seed
//! ├── hier:     Arc<HierarchicalGraph>   coarse + (≤1k) full-CSR fine
//! ├── dense:    Option<Arc<ClusterGraph>>  oracle reference mode only
//! ├── workload: Vec<ModelSpec>           canonical (largest-first)
//! └── padded:   Arc<Mutex<…>>            LRU per artifact slot count:
//!     └── PaddedWorld { csr, feats, mask }   GCN inference tensors
//! ```
//!
//! `with_workload` forks a world that shares the fleet/graph/padded
//! arcs — how `failure_storm` sheds oversized tasks without paying a
//! graph rebuild per retry.

use std::sync::{Arc, Mutex, OnceLock};

use anyhow::Result;

use crate::cluster::Fleet;
use crate::gnn::Classifier;
use crate::graph::{node_features_csr, ClusterGraph, CsrGraph, GraphView,
                   HierarchicalGraph};
use crate::models::ModelSpec;
use crate::planner::{HulkSplitterKind, PlanContext};

/// How many [`PaddedWorld`]s a world retains, LRU — one or two artifact
/// sizes per process is typical, so 4 leaves slack without letting a
/// slot-count sweep hold every tensor set alive at once.
pub const MAX_PADDED_WORLDS: usize = 4;

/// Padded GCN-inference tensors for one artifact slot count: the CSR
/// adjacency view plus features and node mask, all shaped `[slots, …]`.
/// The dense `slots²` adjacency (what the PJRT artifact and the dense
/// oracle consume) is materialized lazily — backends on the CSR path
/// never pay for it.
#[derive(Debug)]
pub struct PaddedWorld {
    pub slots: usize,
    pub csr: CsrGraph,
    pub feats: Vec<f32>,
    pub mask: Vec<f32>,
    dense: OnceLock<Vec<f32>>,
}

impl PaddedWorld {
    /// The dense padded adjacency, built from the CSR view on first use
    /// and cached (identical to `ClusterGraph::padded_adj`).
    pub fn dense_adj(&self) -> &[f32] {
        self.dense.get_or_init(|| self.csr.to_dense())
    }
}

/// The shared per-(scenario, seed) arena. See the module docs.
#[derive(Clone, Debug)]
pub struct ScenarioWorld {
    fleet: Arc<Fleet>,
    hier: Arc<HierarchicalGraph>,
    /// Set only by [`ScenarioWorld::new_dense_oracle`]: plan on the
    /// demoted dense graph instead of the hierarchical substrate, for
    /// the hier-vs-dense byte-identity gate.
    dense: Option<Arc<ClusterGraph>>,
    workload: Vec<ModelSpec>,
    /// Lazily built padded tensors, keyed by slot count, in LRU order
    /// (front = coldest, capped at [`MAX_PADDED_WORLDS`]). Shared
    /// across `with_workload` forks.
    padded: Arc<Mutex<Vec<Arc<PaddedWorld>>>>,
}

impl ScenarioWorld {
    /// Build a world from parts: sorts `workload` into canonical
    /// (largest-first) order and derives the two-level graph once —
    /// directly from the fleet, never through a dense n×n intermediate.
    pub fn new(fleet: Fleet, mut workload: Vec<ModelSpec>)
        -> ScenarioWorld
    {
        ModelSpec::sort_largest_first(&mut workload);
        let fleet = Arc::new(fleet);
        let hier = Arc::new(HierarchicalGraph::from_fleet(fleet.clone()));
        ScenarioWorld {
            fleet,
            hier,
            dense: None,
            workload,
            padded: Arc::new(Mutex::new(Vec::new())),
        }
    }

    /// The dense-oracle reference world: identical to [`Self::new`]
    /// except planners consume the demoted dense [`ClusterGraph`]
    /// (≤1k machines) with no hierarchical context attached. Exists so
    /// the CI parity gate can prove the hierarchical substrate changes
    /// no artifact byte.
    pub fn new_dense_oracle(fleet: Fleet, mut workload: Vec<ModelSpec>)
        -> ScenarioWorld
    {
        ModelSpec::sort_largest_first(&mut workload);
        let dense = Arc::new(ClusterGraph::from_fleet(&fleet));
        let fleet = Arc::new(fleet);
        let hier = Arc::new(HierarchicalGraph::from_fleet(fleet.clone()));
        ScenarioWorld {
            fleet,
            hier,
            dense: Some(dense),
            workload,
            padded: Arc::new(Mutex::new(Vec::new())),
        }
    }

    /// The world of an `Evaluate` scenario body: fleet from the
    /// effective seed, workload on that fleet, canonical order.
    pub fn for_evaluate(fleet: fn(u64) -> Fleet,
                        workload: fn(&Fleet) -> Vec<ModelSpec>,
                        eff_seed: u64) -> ScenarioWorld
    {
        let fl = fleet(eff_seed);
        let wl = workload(&fl);
        ScenarioWorld::new(fl, wl)
    }

    /// [`Self::for_evaluate`] in dense-oracle reference mode.
    pub fn for_evaluate_dense(fleet: fn(u64) -> Fleet,
                              workload: fn(&Fleet) -> Vec<ModelSpec>,
                              eff_seed: u64) -> ScenarioWorld
    {
        let fl = fleet(eff_seed);
        let wl = workload(&fl);
        ScenarioWorld::new_dense_oracle(fl, wl)
    }

    pub fn fleet(&self) -> &Fleet {
        &self.fleet
    }

    /// The two-level graph (always present, even in dense-oracle mode).
    pub fn hier(&self) -> &HierarchicalGraph {
        &self.hier
    }

    /// The graph planners see: the hierarchical substrate, or the
    /// demoted dense oracle in reference mode.
    pub fn view(&self) -> &dyn GraphView {
        match &self.dense {
            Some(d) => &**d,
            None => &*self.hier,
        }
    }

    /// The workload in canonical (largest-first) order.
    pub fn workload(&self) -> &[ModelSpec] {
        &self.workload
    }

    /// A fork with a different workload that **shares** the fleet,
    /// graph, and padded-tensor caches (cheap: a few `Arc` clones plus
    /// the sort).
    pub fn with_workload(&self, mut workload: Vec<ModelSpec>)
        -> ScenarioWorld
    {
        ModelSpec::sort_largest_first(&mut workload);
        ScenarioWorld {
            fleet: self.fleet.clone(),
            hier: self.hier.clone(),
            dense: self.dense.clone(),
            workload,
            padded: self.padded.clone(),
        }
    }

    /// A [`PlanContext`] borrowing this world — the seam every planner
    /// and both cost backends consume. Analytic backend by default;
    /// chain [`PlanContext::with_backend`] to switch. The hierarchical
    /// graph rides along (except in dense-oracle mode) so Hulk-family
    /// planners can go region-first past `HIER_THRESHOLD`.
    pub fn context(&self, splitter: HulkSplitterKind<'_>)
        -> PlanContext<'_>
    {
        let ctx = PlanContext::new(&self.fleet, self.view(),
                                   &self.workload, splitter);
        match &self.dense {
            Some(_) => ctx,
            None => ctx.with_hier(&self.hier),
        }
    }

    /// Classify every machine through the **cached** padded tensors —
    /// the amortized counterpart of [`crate::gnn::classify`]: the CSR
    /// view, features, mask (and, for dense-path backends like the
    /// PJRT artifact, the dense adjacency) are built once per (world,
    /// slot count) and every subsequent call is pure forward + argmax.
    pub fn classify(&self, classifier: &Classifier, params: &[f32])
        -> Result<Vec<usize>>
    {
        let padded = self.padded(classifier.slots());
        let probs = if classifier.uses_csr(&padded.csr) {
            classifier.probs_for_padded(params, &padded.csr,
                                        &padded.feats, &padded.mask)?
        } else {
            // Dense-path backend: feed the cached dense tensor instead
            // of letting `probs_for_padded` re-materialize it per call.
            classifier.probs(params, padded.dense_adj(), &padded.feats,
                             &padded.mask)?
        };
        Ok(crate::gnn::inference::classes_from_probs(
            &probs, self.fleet.len(), classifier.n_classes()))
    }

    /// The padded GCN tensors for `slots` artifact slots, built on
    /// first use and LRU-cached (thread-safe; `--parallel` cells share
    /// the same build; at most [`MAX_PADDED_WORLDS`] slot counts stay
    /// resident and eviction only drops this cache's `Arc` — callers
    /// holding one keep their tensors, and a rebuild is bit-identical
    /// because every tensor is a pure function of (fleet, slots)).
    pub fn padded(&self, slots: usize) -> Arc<PaddedWorld> {
        let mut cache = self.padded.lock().expect("padded cache poisoned");
        if let Some(pos) = cache.iter().position(|p| p.slots == slots) {
            let hit = cache.remove(pos);
            cache.push(hit.clone());
            return hit;
        }
        let csr = self.view().padded_csr(slots);
        let feats = node_features_csr(&self.fleet.machines, &csr);
        let mask = self.view().padded_mask(slots);
        let built = Arc::new(PaddedWorld { slots, csr, feats, mask,
                                           dense: OnceLock::new() });
        cache.push(built.clone());
        if cache.len() > MAX_PADDED_WORLDS {
            cache.remove(0);
        }
        built
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::node_features;

    #[test]
    fn world_canonicalizes_the_workload() {
        let world = ScenarioWorld::new(Fleet::paper_evaluation(0),
                                       ModelSpec::paper_six());
        assert!(crate::planner::is_canonical(world.workload()));
        assert_eq!(world.hier().n_nodes(), world.fleet().len());
        assert!(!world.hier().is_coarse(), "46 machines keep a full fine level");
    }

    #[test]
    fn padded_tensors_match_the_from_scratch_build() {
        let world = ScenarioWorld::new(Fleet::paper_evaluation(0),
                                       ModelSpec::paper_four());
        // Reference: the demoted dense oracle, built independently.
        let dense = ClusterGraph::from_fleet(world.fleet());
        let slots = world.fleet().len() + 18;
        let padded = world.padded(slots);
        assert_eq!(padded.feats,
                   node_features(&world.fleet().machines, &dense, slots));
        assert_eq!(padded.mask, dense.padded_mask(slots));
        assert_eq!(padded.csr, CsrGraph::padded(&dense, slots));
        assert_eq!(padded.dense_adj(), dense.padded_adj(slots));
        // Second request is the cached allocation, not a rebuild.
        let again = world.padded(slots);
        assert!(Arc::ptr_eq(&padded, &again));
        // A different slot count coexists.
        let other = world.padded(slots + 4);
        assert_eq!(other.slots, slots + 4);
    }

    #[test]
    fn cached_classify_matches_the_from_scratch_path() {
        use crate::gnn::{classify, RefGcn, RefGcnConfig};
        use crate::util::rng::Rng;
        let world = ScenarioWorld::new(Fleet::paper_evaluation(0),
                                       ModelSpec::paper_four());
        let cfg = RefGcnConfig { n: 64, f: crate::graph::FEATURE_DIM,
                                 h: 16, h2: 8, c: 8 };
        let mut rng = Rng::new(23);
        let params: Vec<f32> = (0..cfg.n_params())
            .map(|_| (rng.normal() * 0.1) as f32)
            .collect();
        let clf = Classifier::Reference(RefGcn::new(cfg, &params));
        let cached = world.classify(&clf, &params).unwrap();
        assert_eq!(cached,
                   classify(&clf, &params, world.fleet()).unwrap());
        // The call populated the padded cache for the artifact size.
        assert_eq!(world.padded(64).slots, 64);
    }

    #[test]
    fn workload_fork_shares_fleet_graph_and_padded_cache() {
        let world = ScenarioWorld::new(Fleet::paper_evaluation(0),
                                       ModelSpec::paper_four());
        let padded = world.padded(64);
        let fork = world.with_workload(vec![ModelSpec::bert_large()]);
        assert_eq!(fork.workload().len(), 1);
        assert!(std::ptr::eq(world.fleet(), fork.fleet()));
        assert!(std::ptr::eq(world.hier(), fork.hier()));
        assert!(Arc::ptr_eq(&padded, &fork.padded(64)));
    }

    #[test]
    fn fork_workload_mutation_never_invalidates_parent_tensors() {
        // The failure_storm shed-loop shape: repeated forks with
        // mutated workloads must leave the parent's cached GCN tensors
        // bit-identical and its workload untouched — the padded cache
        // is keyed by slot count only, never by workload.
        let world = ScenarioWorld::new(Fleet::paper_evaluation(0),
                                       ModelSpec::paper_four());
        let padded = world.padded(64);
        let feats_before = padded.feats.clone();
        let mask_before = padded.mask.clone();
        let dense_before = padded.dense_adj().to_vec();
        let parent_workload = world.workload().to_vec();
        let mut wl = ModelSpec::paper_six();
        for _ in 0..3 {
            wl.pop();
            let mut small = ModelSpec::bert_large();
            small.batch /= 2;
            wl.push(small);
            let fork = world.with_workload(wl.clone());
            assert!(std::ptr::eq(world.hier(), fork.hier()),
                    "fork must share the Arc'd graph");
            assert!(Arc::ptr_eq(&padded, &fork.padded(64)));
            // A fork growing the shared cache with a new slot count is
            // additive, never an invalidation.
            assert_eq!(fork.padded(96).slots, 96);
        }
        assert_eq!(world.workload(), &parent_workload[..]);
        let after = world.padded(64);
        assert!(Arc::ptr_eq(&padded, &after));
        assert_eq!(after.feats, feats_before);
        assert_eq!(after.mask, mask_before);
        assert_eq!(after.dense_adj(), &dense_before[..]);
        // The slot count a fork built is visible to the parent — one
        // shared cache, not a copy-on-write.
        assert_eq!(world.padded(96).slots, 96);
    }

    #[test]
    fn lru_eviction_never_changes_artifacts() {
        // Satellite: the padded cache is bounded. Walking more slot
        // counts than the cap evicts the coldest entry, and a rebuild
        // after eviction is bit-identical — eviction is a memory
        // decision, never an artifact one.
        let world = ScenarioWorld::new(Fleet::paper_evaluation(0),
                                       ModelSpec::paper_four());
        let base = 64;
        let first = world.padded(base);
        let feats = first.feats.clone();
        let mask = first.mask.clone();
        let csr = first.csr.clone();
        // Touch `base` mid-walk: the LRU hit keeps it resident while
        // older counts fall out.
        for extra in 1..MAX_PADDED_WORLDS {
            world.padded(base + 8 * extra);
        }
        assert!(Arc::ptr_eq(&first, &world.padded(base)),
                "a touched entry survives a full-capacity walk");
        // Now flood past capacity without touching `base`.
        for extra in 0..=MAX_PADDED_WORLDS {
            world.padded(base + 100 + 8 * extra);
        }
        let rebuilt = world.padded(base);
        assert!(!Arc::ptr_eq(&first, &rebuilt),
                "flooding {} fresh slot counts must evict the cold entry",
                MAX_PADDED_WORLDS + 1);
        assert_eq!(rebuilt.feats, feats);
        assert_eq!(rebuilt.mask, mask);
        assert_eq!(rebuilt.csr, csr);
        // The evicted Arc the caller still holds is untouched.
        assert_eq!(first.feats, feats);
    }

    #[test]
    fn dense_oracle_world_plans_identically() {
        use crate::planner::{HulkPlanner, Planner};
        let hier_world = ScenarioWorld::new(Fleet::paper_evaluation(0),
                                            ModelSpec::paper_four());
        let dense_world =
            ScenarioWorld::new_dense_oracle(Fleet::paper_evaluation(0),
                                            ModelSpec::paper_four());
        // The dense world plans with no hierarchical context…
        let dctx = dense_world.context(HulkSplitterKind::Oracle);
        assert!(dctx.hier.is_none());
        let hctx = hier_world.context(HulkSplitterKind::Oracle);
        assert!(hctx.hier.is_some());
        // …and both substrates emit the same placements and tensors.
        let p = HulkPlanner;
        assert_eq!(p.plan(&hctx).unwrap(), p.plan(&dctx).unwrap());
        let a = hier_world.padded(64);
        let b = dense_world.padded(64);
        assert_eq!(a.feats, b.feats);
        assert_eq!(a.mask, b.mask);
        assert_eq!(a.csr, b.csr);
    }

    #[test]
    fn context_borrows_the_world() {
        let world = ScenarioWorld::new(Fleet::paper_evaluation(0),
                                       ModelSpec::paper_four());
        let ctx = world.context(HulkSplitterKind::Oracle);
        assert_eq!(ctx.workload.len(), 4);
        assert!(std::ptr::eq(ctx.fleet, world.fleet()));
        // `ctx.graph` is a fat pointer — compare data addresses.
        assert!(std::ptr::eq(
            ctx.graph as *const dyn GraphView as *const u8,
            world.view() as *const dyn GraphView as *const u8));
        assert!(std::ptr::eq(ctx.hier.expect("hier rides along"),
                             world.hier()));
    }
}
