//! The named-scenario registry: every entry deterministically runs the
//! paper's four systems (A/B/C/Hulk) over one fleet/workload situation and
//! emits machine-readable [`BenchEntry`] rows for `BENCH_*.json`.
//!
//! Scenarios exist so the headline claim — Hulk >20% over the best
//! baseline — is tracked across *many* WAN/fleet situations, not just the
//! paper's Table 1 testbed: WAN degradation, heterogeneous GPU fleets,
//! fleet growth, failure storms and multi-tenant streaming arrivals.
//! Everything is a pure function of the seed: no wall clock, no global
//! state, so two runs with the same seed produce identical entries.
//!
//! CLI: `hulk scenarios list` and `hulk scenarios run <name…|all>
//! [--seed S] [--json] [--out DIR]`.

use anyhow::Result;

use crate::benchkit::BenchEntry;
use crate::cluster::paper_data::fig6_node_45;
use crate::cluster::{Fleet, GpuModel, Machine, Region, WanModel};
use crate::coordinator::{scale_out, Coordinator, CoordinatorEvent,
                         CoordinatorReply, RecoveryAction};
use crate::graph::ClusterGraph;
use crate::models::ModelSpec;
use crate::parallel::pipeline_cost;
use crate::scheduler::{oracle_partition, Assignment, OracleOptions};
use crate::sim::{simulate_pipeline, FailurePlan};
use crate::systems::hulk::{hulk_plan, HulkSplitterKind};
use crate::systems::{system_a, system_b, system_c};
use crate::util::rng::Rng;
use crate::util::table::{fmt_ms, Table};

use super::evaluate::{evaluate_all, SystemEval, SystemKind};
use super::sweep::{feasible_workload, fleet_size_sweep, truncated_fleet};

/// A registered scenario: a name, a one-line description, and a
/// deterministic runner `seed → result`.
pub struct Scenario {
    pub name: &'static str,
    pub description: &'static str,
    runner: fn(u64) -> Result<ScenarioResult>,
}

impl Scenario {
    pub fn run(&self, seed: u64) -> Result<ScenarioResult> {
        (self.runner)(seed)
    }
}

/// Output of one scenario run.
pub struct ScenarioResult {
    pub scenario: &'static str,
    /// Machine-readable rows for the `BENCH_*.json` report.
    pub entries: Vec<BenchEntry>,
    /// Human-readable rendering for the CLI.
    pub rendered: String,
}

/// Every registered scenario, in canonical order.
pub fn all_scenarios() -> Vec<Scenario> {
    vec![
        Scenario {
            name: "table1_fleet",
            description: "Paper §6.1 fleet (46 servers, Table 1 WAN), \
                          four-model workload under all four systems",
            runner: table1_fleet,
        },
        Scenario {
            name: "wan_degradation",
            description: "Every inter-region latency scaled ×1..×8; \
                          systems compared on the ×4 WAN",
            runner: wan_degradation,
        },
        Scenario {
            name: "hetero_gpu",
            description: "20-server fleet with per-machine GPU models \
                          drawn from the full catalog (A100 … TITAN Xp)",
            runner: hetero_gpu,
        },
        Scenario {
            name: "fleet_growth",
            description: "Fleet grown 12→46 servers plus the Fig. 6 \
                          node-45 scale-out join",
            runner: fleet_growth,
        },
        Scenario {
            name: "failure_storm",
            description: "Five machine failures against the leader's \
                          recovery policy, then systems on the survivors",
            runner: failure_storm,
        },
        Scenario {
            name: "multi_tenant",
            description: "Six models arriving as a stream through the \
                          leader loop with a mid-stream failure",
            runner: multi_tenant,
        },
    ]
}

/// Look up a scenario by name.
pub fn find_scenario(name: &str) -> Option<Scenario> {
    all_scenarios().into_iter().find(|s| s.name == name)
}

/// Run every scenario with one seed.
pub fn run_all(seed: u64) -> Result<Vec<ScenarioResult>> {
    all_scenarios().iter().map(|s| s.run(seed)).collect()
}

/// Lowercase ascii-alnum slug for entry names: `"OPT (175B)"` →
/// `"opt_175b"`.
fn slug(name: &str) -> String {
    let mut out = String::new();
    for ch in name.chars() {
        if ch.is_ascii_alphanumeric() {
            out.push(ch.to_ascii_lowercase());
        } else if !out.is_empty() && !out.ends_with('_') {
            out.push('_');
        }
    }
    out.trim_end_matches('_').to_string()
}

/// Per-model × per-system `iter_ms` rows (feasible combinations only).
fn eval_entries(prefix: &str, eval: &SystemEval) -> Vec<BenchEntry> {
    let mut out = Vec::new();
    for (m, model) in eval.models.iter().enumerate() {
        for (s, kind) in SystemKind::ALL.iter().enumerate() {
            let c = eval.costs[m][s];
            if c.is_feasible() {
                out.push(BenchEntry::new(
                    format!("{prefix}/{}/{}/iter_ms", kind.slug(),
                            slug(model.name)),
                    c.total_ms(),
                    "ms",
                ));
            }
        }
    }
    out
}

fn improvement_entry(prefix: &str, eval: &SystemEval) -> BenchEntry {
    BenchEntry::new(
        format!("{prefix}/hulk_improvement_pct"),
        eval.hulk_improvement() * 100.0,
        "%",
    )
}

/// The shared Fig. 6 scale-out procedure (used by both the `fig6` bench
/// and the `fleet_growth` scenario): drop node 45 from the evaluation
/// fleet, oracle-partition the four-model workload, then join the
/// paper's node `{Rome, 7, 384}`. Returns the grown fleet, the updated
/// assignment, the size-sorted tasks, the joined machine id, the task it
/// joined (None = spare pool), and the pre-join intra-group cost.
pub(crate) fn fig6_scale_out(seed: u64)
    -> (Fleet, Assignment, Vec<ModelSpec>, usize, Option<usize>, f64)
{
    let mut fleet = Fleet::paper_evaluation(seed);
    fleet.remove_machine(45);
    let graph = ClusterGraph::from_fleet(&fleet);
    let mut tasks = ModelSpec::paper_four();
    tasks.sort_by(|a, b| b.params.partial_cmp(&a.params).unwrap());
    let mut assignment = oracle_partition(&fleet, &graph, &tasks,
                                          &OracleOptions::default());
    let before_cost = assignment.total_cost(&graph);
    let spec = fig6_node_45();
    let (id, joined) = scale_out(&mut fleet, &mut assignment, &tasks,
                                 spec.region, spec.gpu, spec.n_gpus);
    (fleet, assignment, tasks, id, joined, before_cost)
}

// ------------------------------------------------------------ scenarios --

/// The paper's own evaluation situation (Table 1 WAN + §6.1 fleet).
fn table1_fleet(seed: u64) -> Result<ScenarioResult> {
    let fleet = Fleet::paper_evaluation(seed);
    let eval = evaluate_all(&fleet, &ModelSpec::paper_four(),
                            HulkSplitterKind::Oracle)?;
    let mut entries = eval_entries("table1_fleet", &eval);
    entries.push(improvement_entry("table1_fleet", &eval));
    let rendered = format!(
        "{}\nHulk improvement over best feasible baseline: {:.1}% \
         (paper claims >20%)\n",
        eval.render(),
        eval.hulk_improvement() * 100.0
    );
    Ok(ScenarioResult { scenario: "table1_fleet", entries, rendered })
}

/// WAN degradation ×1..×8; the ×4 WAN gets the full system comparison.
/// Each factor is evaluated exactly once (no second pass through the
/// sweep for the table).
fn wan_degradation(seed: u64) -> Result<ScenarioResult> {
    let workload = ModelSpec::paper_four();
    let mut entries = Vec::new();
    let mut t = Table::new(&["factor", "Hulk improvement"]);
    let mut x4_render = String::new();
    for factor in [1.0, 2.0, 4.0, 8.0] {
        let fleet = Fleet::paper_evaluation(seed).with_wan_scaled(factor);
        let eval = evaluate_all(&fleet, &workload,
                                HulkSplitterKind::Oracle)?;
        entries.push(BenchEntry::new(
            format!("wan_degradation/x{factor:.0}/hulk_improvement_pct"),
            eval.hulk_improvement() * 100.0,
            "%",
        ));
        t.row(&[format!("×{factor:.0}"),
                format!("{:.1}%", eval.hulk_improvement() * 100.0)]);
        if factor == 4.0 {
            entries.extend(eval_entries("wan_degradation/x4", &eval));
            x4_render = eval.render();
        }
    }
    let rendered = format!(
        "— improvement vs degradation factor —\n{}\n— all systems on \
         the ×4 WAN —\n{x4_render}",
        t.render()
    );
    Ok(ScenarioResult { scenario: "wan_degradation", entries, rendered })
}

/// Heterogeneous fleet: 20 servers over five well-connected regions, GPU
/// model and count drawn per machine from the full catalog.
fn hetero_gpu(seed: u64) -> Result<ScenarioResult> {
    let regions = [Region::California, Region::Tokyo, Region::Berlin,
                   Region::London, Region::Rome];
    let mut rng = Rng::new(seed ^ 0x4845_5445_524F); // "HETERO"
    let mut machines = Vec::new();
    for i in 0..20 {
        let region = regions[i % regions.len()];
        let gpu = GpuModel::ALL[rng.below(GpuModel::ALL.len())];
        let n_gpus = [4, 8, 8, 12][rng.below(4)];
        machines.push(Machine::new(i, region, gpu, n_gpus));
    }
    let fleet = Fleet::new(machines, WanModel::new(seed));
    let workload = vec![ModelSpec::t5_11b(), ModelSpec::gpt2_xl(),
                        ModelSpec::bert_large()];
    let eval = evaluate_all(&fleet, &workload, HulkSplitterKind::Oracle)?;
    let mut entries = eval_entries("hetero_gpu", &eval);
    entries.push(improvement_entry("hetero_gpu", &eval));
    entries.push(BenchEntry::new(
        "hetero_gpu/fleet_total_memory_gb",
        fleet.total_memory_gb(),
        "GB",
    ));
    let rendered = format!(
        "fleet: {} servers / {} GPUs / {:.1} TB over {} regions\n{}\n\
         Hulk improvement: {:.1}%\n",
        fleet.len(),
        fleet.total_gpus(),
        fleet.total_memory_gb() / 1e3,
        regions.len(),
        eval.render(),
        eval.hulk_improvement() * 100.0
    );
    Ok(ScenarioResult { scenario: "hetero_gpu", entries, rendered })
}

/// Fleet growth 12→46 plus the Fig. 6 scale-out join.
fn fleet_growth(seed: u64) -> Result<ScenarioResult> {
    let workload = ModelSpec::paper_four();
    let sizes = [12usize, 16, 24, 32, 46];
    let points = fleet_size_sweep(seed, &sizes, &workload)?;
    let mut entries = Vec::new();
    let mut t = Table::new(&["servers", "Hulk improvement"]);
    for p in &points {
        entries.push(BenchEntry::new(
            format!("fleet_growth/n{:.0}/hulk_improvement_pct", p.x),
            p.improvement * 100.0,
            "%",
        ));
        t.row(&[format!("{:.0}", p.x),
                format!("{:.1}%", p.improvement * 100.0)]);
    }

    // Mid-growth checkpoint: all four systems on the 24-server fleet.
    let mid = truncated_fleet(&Fleet::paper_evaluation(seed), 24);
    let mid_workload = feasible_workload(&mid, &workload);
    let eval = evaluate_all(&mid, &mid_workload, HulkSplitterKind::Oracle)?;
    entries.extend(eval_entries("fleet_growth/n24", &eval));
    entries.push(improvement_entry("fleet_growth/n24", &eval));

    // Fig. 6: node 45 {Rome, 7, 384} joins the 45-server system.
    let (fleet46, assignment, tasks, id, joined, _before_cost) =
        fig6_scale_out(seed);
    let graph46 = ClusterGraph::from_fleet(&fleet46);
    assignment
        .validate_disjoint(fleet46.len())
        .map_err(|e| anyhow::anyhow!(e))?;
    assignment
        .validate_memory(&fleet46, &tasks)
        .map_err(|e| anyhow::anyhow!(e))?;
    entries.push(BenchEntry::new(
        "fleet_growth/scale_out/joined_task",
        if joined.is_some() { 1.0 } else { 0.0 },
        "count",
    ));
    entries.push(BenchEntry::new(
        "fleet_growth/scale_out/total_cost",
        assignment.total_cost(&graph46),
        "ms_edges",
    ));
    let rendered = format!(
        "— improvement vs fleet size —\n{}\n— 24-server checkpoint —\n{}\n\
         node {id} {} joined → {}\n",
        t.render(),
        eval.render(),
        fig6_node_45().label(),
        match joined {
            Some(task) => format!("task {task}"),
            None => "spare pool".to_string(),
        }
    );
    Ok(ScenarioResult { scenario: "fleet_growth", entries, rendered })
}

/// Five machine failures against the leader's recovery policy, then the
/// four systems re-evaluated on the surviving fleet, plus a DES run with
/// a mid-iteration failure.
fn failure_storm(seed: u64) -> Result<ScenarioResult> {
    let fleet = Fleet::paper_evaluation(seed);
    let mut coordinator = Coordinator::new(fleet.clone());
    for model in ModelSpec::paper_four() {
        coordinator.handle(CoordinatorEvent::Submit { model,
                                                      iterations: 100 });
    }

    let mut rng = Rng::new(seed ^ 0x5354_4F52_4D21); // "STORM!"
    let mut victims: Vec<usize> = Vec::new();
    while victims.len() < 5 {
        let v = rng.below(fleet.len());
        if !victims.contains(&v) {
            victims.push(v);
        }
    }
    // Recovery action histogram, indexed promote/shrink/requeue/noop.
    let mut counts = [0usize; 4];
    for &victim in &victims {
        if let CoordinatorReply::Recovered { action } = coordinator
            .handle(CoordinatorEvent::MachineFailed { machine: victim })
        {
            let idx = match action {
                RecoveryAction::PromoteSpare { .. } => 0,
                RecoveryAction::ShrinkGroup { .. } => 1,
                RecoveryAction::Requeue { .. } => 2,
                RecoveryAction::NoOp => 3,
            };
            counts[idx] += 1;
        }
    }
    let mut entries = Vec::new();
    for (label, &n) in ["promote_spare", "shrink_group", "requeue", "noop"]
        .iter()
        .zip(&counts)
    {
        entries.push(BenchEntry::new(
            format!("failure_storm/recovery/{label}"),
            n as f64,
            "count",
        ));
    }

    // The four systems on the surviving fleet. Remove victims largest-id
    // first so earlier removals do not shift later ids.
    let mut survivors = fleet.clone();
    let mut doomed = victims.clone();
    doomed.sort_unstable();
    for &victim in doomed.iter().rev() {
        survivors.remove_machine(victim);
    }
    entries.push(BenchEntry::new("failure_storm/survivor_count",
                                 survivors.len() as f64, "count"));
    let mut workload = feasible_workload(&survivors,
                                         &ModelSpec::paper_four());
    // The storm can leave too little contiguous memory for the largest
    // model; deterministically shed largest-first until Algorithm 1
    // accepts (paper: such tasks queue until resources return).
    let eval = loop {
        match evaluate_all(&survivors, &workload,
                           HulkSplitterKind::Oracle) {
            Ok(eval) => break eval,
            Err(_) if workload.len() > 1 => {
                workload.remove(0);
            }
            Err(e) => return Err(e),
        }
    };
    entries.extend(eval_entries("failure_storm/survivors", &eval));
    entries.push(improvement_entry("failure_storm/survivors", &eval));

    // DES: interrupt the largest surviving Hulk pipeline mid-iteration.
    let graph = ClusterGraph::from_fleet(&survivors);
    let plan = hulk_plan(&survivors, &graph, &workload,
                         HulkSplitterKind::Oracle)?;
    let pipe = &plan.pipelines[0];
    let mut sim_note = String::new();
    if pipe.stages.len() > 1
        && pipeline_cost(&survivors, pipe, &plan.tasks[0]).is_feasible()
    {
        let healthy =
            simulate_pipeline(&survivors, pipe, &plan.tasks[0], false, None);
        entries.push(BenchEntry::new(
            "failure_storm/sim/healthy_makespan_ms",
            healthy.makespan_ms,
            "ms",
        ));
        let injected = FailurePlan {
            at_ms: healthy.makespan_ms * 0.5,
            machine: pipe.stages[1],
        };
        let interrupted = simulate_pipeline(&survivors, pipe,
                                            &plan.tasks[0], false,
                                            Some(injected));
        if let Some(outcome) = interrupted.failure {
            entries.push(BenchEntry::new(
                "failure_storm/sim/microbatches_salvaged",
                outcome.completed_microbatches as f64,
                "count",
            ));
            sim_note = format!(
                "DES: stage machine {} killed at {} → {} of {} \
                 microbatches salvaged\n",
                outcome.machine,
                fmt_ms(outcome.at_ms),
                outcome.completed_microbatches,
                pipe.microbatches
            );
        }
    }

    let rendered = format!(
        "failed machines: {victims:?}\nrecovery actions: promote-spare \
         {} | shrink {} | requeue {} | noop {}\n{}— systems on the {} \
         survivors —\n{}\nHulk improvement: {:.1}%\n",
        counts[0], counts[1], counts[2], counts[3], sim_note,
        survivors.len(),
        eval.render(),
        eval.hulk_improvement() * 100.0
    );
    Ok(ScenarioResult { scenario: "failure_storm", entries, rendered })
}

/// Six models arriving as a stream through the leader loop, with a
/// mid-stream machine failure; baselines costed on the same arrivals.
fn multi_tenant(seed: u64) -> Result<ScenarioResult> {
    let fleet = Fleet::paper_evaluation(seed);
    let mut rng = Rng::new(seed ^ 0x4D54_454E_414E); // "MTENAN"
    let mut arrivals = ModelSpec::paper_six();
    rng.shuffle(&mut arrivals);

    let mut coordinator = Coordinator::new(fleet.clone());
    for (i, model) in arrivals.iter().enumerate() {
        coordinator.handle(CoordinatorEvent::Submit {
            model: model.clone(),
            iterations: 30,
        });
        if i == 2 {
            let victim = rng.below(fleet.len());
            coordinator
                .handle(CoordinatorEvent::MachineFailed { machine: victim });
        }
        coordinator.handle(CoordinatorEvent::Tick { iterations: 10 });
    }
    // Drain: completed tasks free machines for whatever queued.
    for _ in 0..10 {
        coordinator.handle(CoordinatorEvent::Tick { iterations: 30 });
    }

    let mut entries = Vec::new();
    for counter in ["tasks_admitted", "tasks_queued", "machine_failures"] {
        entries.push(BenchEntry::new(
            format!("multi_tenant/{counter}"),
            coordinator.metrics.counter(counter) as f64,
            "count",
        ));
    }
    // Hulk: per-task iteration time on the leader's disjoint groups.
    let mut t = Table::new(&["task", "group size", "iter"]);
    for task in &coordinator.tasks {
        if task.machines.is_empty() {
            continue;
        }
        if let Some(ms) = coordinator.task_iter_ms(task) {
            entries.push(BenchEntry::new(
                format!("multi_tenant/hulk/{}/iter_ms",
                        slug(task.model.name)),
                ms,
                "ms",
            ));
            t.row(&[task.model.name.to_string(),
                    task.machines.len().to_string(), fmt_ms(ms)]);
        }
    }
    // Baselines get the whole (pristine) fleet per model — that is their
    // defining weakness in a multi-tenant setting.
    for model in &arrivals {
        for (kind, cost) in [
            (SystemKind::SystemA, system_a::cost(&fleet, model)),
            (SystemKind::SystemB, system_b::cost(&fleet, model)),
            (SystemKind::SystemC, system_c::cost(&fleet, model)),
        ] {
            if cost.is_feasible() {
                entries.push(BenchEntry::new(
                    format!("multi_tenant/{}/{}/iter_ms", kind.slug(),
                            slug(model.name)),
                    cost.total_ms(),
                    "ms",
                ));
            }
        }
    }

    let arrival_names: Vec<&str> =
        arrivals.iter().map(|m| m.name).collect();
    let rendered = format!(
        "arrival order: {}\nadmitted {} | queued {} | failures {}\n\
         — Hulk groups (leader loop) —\n{}",
        arrival_names.join(" → "),
        coordinator.metrics.counter("tasks_admitted"),
        coordinator.metrics.counter("tasks_queued"),
        coordinator.metrics.counter("machine_failures"),
        t.render()
    );
    Ok(ScenarioResult { scenario: "multi_tenant", entries, rendered })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slugs_compress_model_names() {
        assert_eq!(slug("OPT (175B)"), "opt_175b");
        assert_eq!(slug("GPT-2 (1.5B)"), "gpt_2_1_5b");
        assert_eq!(slug("System A (DP)"), "system_a_dp");
        assert_eq!(slug("___"), "");
    }

    #[test]
    fn registry_is_populated_with_unique_names() {
        let scenarios = all_scenarios();
        assert!(scenarios.len() >= 6);
        let mut names: Vec<&str> =
            scenarios.iter().map(|s| s.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), scenarios.len());
        assert!(find_scenario("table1_fleet").is_some());
        assert!(find_scenario("no_such_scenario").is_none());
    }

    #[test]
    fn fig6_helper_produces_valid_assignment() {
        let (fleet, assignment, tasks, id, _joined, before_cost) =
            fig6_scale_out(0);
        assert_eq!(id, 45);
        assert_eq!(fleet.len(), 46);
        assert!(before_cost > 0.0);
        assignment.validate_disjoint(fleet.len()).unwrap();
        assignment.validate_memory(&fleet, &tasks).unwrap();
    }

    #[test]
    fn eval_entries_skip_infeasible_cells() {
        let fleet = Fleet::paper_evaluation(0);
        let eval = evaluate_all(&fleet, &ModelSpec::paper_four(),
                                HulkSplitterKind::Oracle)
            .unwrap();
        let entries = eval_entries("x", &eval);
        // System A × OPT-175B is infeasible → no row for it.
        assert!(entries
            .iter()
            .all(|e| e.name != "x/system_a/opt_175b/iter_ms"));
        assert!(entries
            .iter()
            .any(|e| e.name == "x/hulk/opt_175b/iter_ms"));
        assert!(entries.iter().all(|e| e.value.is_finite()));
    }
}
