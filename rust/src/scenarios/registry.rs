//! The named-scenario registry: every entry deterministically runs one
//! fleet/workload situation through the registered planners (the paper's
//! Systems A/B/C/Hulk by default) and emits machine-readable
//! [`BenchEntry`] rows for `BENCH_*.json`.
//!
//! Scenarios exist so the headline claim — Hulk >20% over the best
//! baseline — is tracked across *many* WAN/fleet situations, not just the
//! paper's Table 1 testbed: WAN degradation, heterogeneous GPU fleets,
//! fleet growth, failure storms, multi-tenant streaming arrivals,
//! planet-scale synthetic fleets and bursty Poisson task streams.
//!
//! Since the runner refactor, a scenario is **data**: a
//! [`ScenarioSpec`] with a seed policy and a body — either the standard
//! `Evaluate` shape (fleet builder + workload, fanned out as one cell
//! per registered planner by [`super::runner`]) or a `Custom` function
//! for leader-loop streams and multi-step sweeps. Custom bodies receive
//! the [`PlannerRegistry`] too, so their baseline comparisons honor the
//! CLI's `--systems` filter. Everything is a pure function of the seed:
//! no wall clock, no global state, so two runs with the same seed
//! produce identical entries — serial or parallel.
//!
//! CLI: `hulk scenarios list` and `hulk scenarios run <name…|all>
//! [--seed S] [--systems a,b,hulk] [--json] [--out DIR] [--parallel]
//! [--threads N]`.

use std::collections::BTreeSet;
use std::sync::Arc;

use anyhow::Result;

use crate::benchkit::BenchEntry;
use crate::cluster::paper_data::fig6_node_45;
use crate::cluster::{Fleet, GpuModel, Machine, Region, WanModel};
use crate::coordinator::{scale_out, Coordinator, CoordinatorEvent,
                         CoordinatorReply, RecoveryAction, TaskState};
use crate::graph::{ClusterGraph, HierarchicalGraph};
use crate::models::ModelSpec;
use crate::parallel::{pipeline_cost, IterCost};
use crate::planner::{CostBackend, HulkSplitterKind, PlanContext, Planner,
                     PlannerKind, PlannerRegistry};
use crate::scheduler::{oracle_partition, Assignment, OracleOptions};
use crate::sim::{simulate_pipeline, FailurePlan};
use crate::util::rng::Rng;
use crate::util::table::{fmt_ms, Table};

use super::evaluate::{evaluate_with_backend, evaluate_world, SystemEval};
use super::generator::{check_case, generate_case, CheckOptions};
use super::runner::{exec_entries, placement_entries, run_specs,
                    ScenarioBody, ScenarioResult, ScenarioSpec,
                    SeedPolicy};
use super::sweep::{feasible_workload, fleet_size_sweep, truncated_fleet};
use super::world::ScenarioWorld;

/// Every registered scenario, in canonical order. The trailing
/// `sim_only` entries exist only under `--cost sim` (they measure
/// shared-link contention, which the analytic backend cannot see);
/// [`resolve_scenarios`] filters them per backend.
pub fn all_scenarios() -> Vec<ScenarioSpec> {
    vec![
        ScenarioSpec {
            name: "table1_fleet",
            description: "Paper §6.1 fleet (46 servers, Table 1 WAN), \
                          four-model workload under all four systems",
            seed: SeedPolicy::Global,
            body: ScenarioBody::Evaluate {
                fleet: Fleet::paper_evaluation,
                workload: |_| ModelSpec::paper_four(),
                finish: table1_finish,
            },
            sim_only: false,
            heavy: false,
        },
        ScenarioSpec {
            name: "wan_degradation",
            description: "Every inter-region latency scaled ×1..×8; \
                          systems compared on the ×4 WAN",
            seed: SeedPolicy::Global,
            body: ScenarioBody::Custom(wan_degradation),
            sim_only: false,
            heavy: false,
        },
        ScenarioSpec {
            name: "hetero_gpu",
            description: "20-server fleet with per-machine GPU models \
                          drawn from the full catalog (A100 … TITAN Xp)",
            seed: SeedPolicy::Global,
            body: ScenarioBody::Evaluate {
                fleet: hetero_fleet,
                workload: |_| vec![ModelSpec::t5_11b(), ModelSpec::gpt2_xl(),
                                   ModelSpec::bert_large()],
                finish: hetero_finish,
            },
            sim_only: false,
            heavy: false,
        },
        ScenarioSpec {
            name: "fleet_growth",
            description: "Fleet grown 12→46 servers plus the Fig. 6 \
                          node-45 scale-out join",
            seed: SeedPolicy::Global,
            body: ScenarioBody::Custom(fleet_growth),
            sim_only: false,
            heavy: false,
        },
        ScenarioSpec {
            name: "failure_storm",
            description: "Five machine failures against the leader's \
                          recovery policy, then systems on the survivors",
            seed: SeedPolicy::Global,
            body: ScenarioBody::Custom(failure_storm),
            sim_only: false,
            heavy: false,
        },
        ScenarioSpec {
            name: "multi_tenant",
            description: "Six models arriving as a stream through the \
                          leader loop with a mid-stream failure",
            seed: SeedPolicy::Global,
            body: ScenarioBody::Custom(multi_tenant),
            sim_only: false,
            heavy: false,
        },
        ScenarioSpec {
            name: "planet_scale",
            description: "Synthetic 220-server fleet over all 12 regions \
                          (great-circle WAN), six-model workload",
            seed: SeedPolicy::Global,
            body: ScenarioBody::Evaluate {
                fleet: |seed| Fleet::synthetic(220, 12, seed),
                workload: |fleet| {
                    feasible_workload(fleet, &ModelSpec::paper_six())
                },
                finish: planet_finish,
            },
            sim_only: false,
            heavy: false,
        },
        ScenarioSpec {
            name: "burst_arrivals",
            description: "Poisson-like seeded task bursts through the \
                          leader loop, with mid-storm machine failures",
            seed: SeedPolicy::Tagged(0x4255_5253_5421), // "BURST!"
            body: ScenarioBody::Custom(burst_arrivals),
            sim_only: false,
            heavy: false,
        },
        ScenarioSpec {
            name: "contended_links",
            description: "Five models on a two-region fleet sharing one \
                          trans-Pacific link — DES-only contention study \
                          (requires --cost sim)",
            seed: SeedPolicy::Tagged(0x5041_4349_4649_43), // "PACIFIC"
            body: ScenarioBody::Custom(contended_links),
            sim_only: true,
            heavy: false,
        },
        ScenarioSpec {
            name: "sim_vs_analytic",
            description: "Per-system gap between closed-form pricing and \
                          contended execution on the Table 1 fleet \
                          (requires --cost sim)",
            seed: SeedPolicy::Global,
            body: ScenarioBody::Custom(sim_vs_analytic),
            sim_only: true,
            heavy: false,
        },
        ScenarioSpec {
            name: "generated_sweep",
            description: "Seeded random (fleet, workload, failure) \
                          cases through every planner with the \
                          property checks on (requires --cost sim)",
            seed: SeedPolicy::Tagged(0x4745_4E53_5745_4550), // "GENSWEEP"
            body: ScenarioBody::Custom(generated_sweep),
            sim_only: true,
            heavy: false,
        },
        ScenarioSpec {
            name: "continent_scale",
            description: "Synthetic 10k-server fleet planned \
                          region-first through the hierarchical graph — \
                          the dense adjacency is never built (heavy: \
                          excluded from `all`, run by name)",
            seed: SeedPolicy::Tagged(0x434F_4E54_494E), // "CONTIN"
            body: ScenarioBody::Custom(continent_scale),
            sim_only: false,
            heavy: true,
        },
        ScenarioSpec {
            name: "global_scale",
            description: "Synthetic 100k-server fleet: hierarchical \
                          planning plus a machine-failure replan, never \
                          densified (heavy: excluded from `all`, run by \
                          name)",
            seed: SeedPolicy::Tagged(0x474C_4F42_414C), // "GLOBAL"
            body: ScenarioBody::Custom(global_scale),
            sim_only: false,
            heavy: true,
        },
    ]
}

/// Look up a scenario by name.
pub fn find_scenario(name: &str) -> Option<ScenarioSpec> {
    all_scenarios().into_iter().find(|s| s.name == name)
}

/// Resolve CLI scenario names to specs under `backend`. An empty list or
/// any `"all"` selects the registry — minus the `sim_only` scenarios
/// when the backend is analytic, which keeps the default artifact
/// byte-identical to its pre-backend shape. **Every** given name is
/// validated first, so a typo can never silently run the wrong suite;
/// the error lists the valid names, and naming a `sim_only` scenario
/// under the analytic backend errors with a pointer to `--cost sim`.
/// A subset keeps the user's order (duplicates included, as before).
pub fn resolve_scenarios(names: &[String], backend: CostBackend)
    -> Result<(Vec<ScenarioSpec>, bool)>
{
    let all = all_scenarios();
    let unknown: Vec<&str> = names
        .iter()
        .map(String::as_str)
        .filter(|&n| n != "all" && !all.iter().any(|s| s.name == n))
        .collect();
    if !unknown.is_empty() {
        let valid: Vec<&str> = all.iter().map(|s| s.name).collect();
        anyhow::bail!(
            "unknown scenario{} {unknown:?}; valid names: {} (or `all`)",
            if unknown.len() > 1 { "s" } else { "" },
            valid.join(", ")
        );
    }
    if backend == CostBackend::Analytic {
        if let Some(blocked) = names.iter().find(|n| {
            all.iter().any(|s| s.name == n.as_str() && s.sim_only)
        }) {
            let sim_names: Vec<&str> = all
                .iter()
                .filter(|s| s.sim_only)
                .map(|s| s.name)
                .collect();
            let analytic_names: Vec<&str> = all
                .iter()
                .filter(|s| !s.sim_only)
                .map(|s| s.name)
                .collect();
            anyhow::bail!(
                "scenario {blocked:?} only runs on the discrete-event \
                 backend; add --cost sim (sim-only scenarios: {}) or \
                 pick an analytic-capable one: {}",
                sim_names.join(", "),
                analytic_names.join(", ")
            );
        }
    }
    if names.is_empty() || names.iter().any(|n| n == "all") {
        // Heavy scale scenarios never ride along with `all` (either
        // backend) — their 10k–100k fleets would dwarf the rest of the
        // suite; name them explicitly to run them.
        let specs: Vec<ScenarioSpec> = all
            .into_iter()
            .filter(|s| backend == CostBackend::Simulated || !s.sim_only)
            .filter(|s| !s.heavy)
            .collect();
        return Ok((specs, true));
    }
    let picked: Vec<ScenarioSpec> = names
        .iter()
        .map(|n| {
            all.iter()
                .find(|s| s.name == n.as_str())
                .expect("validated above")
                .clone()
        })
        .collect();
    Ok((picked, false))
}

/// Run every analytic-backend scenario with one seed, serially, under
/// the standard four systems.
pub fn run_all(seed: u64) -> Result<Vec<ScenarioResult>> {
    let (specs, _) = resolve_scenarios(&[], CostBackend::Analytic)?;
    run_specs(&specs, seed, 1, &PlannerRegistry::standard(),
              CostBackend::Analytic)
}

/// Lowercase ascii-alnum slug for entry names: `"OPT (175B)"` →
/// `"opt_175b"`.
fn slug(name: &str) -> String {
    let mut out = String::new();
    for ch in name.chars() {
        if ch.is_ascii_alphanumeric() {
            out.push(ch.to_ascii_lowercase());
        } else if !out.is_empty() && !out.ends_with('_') {
            out.push('_');
        }
    }
    out.trim_end_matches('_').to_string()
}

/// Per-model × per-planner `iter_ms` rows (feasible combinations only).
fn eval_entries(prefix: &str, eval: &SystemEval) -> Vec<BenchEntry> {
    let mut out = Vec::new();
    for (m, model) in eval.models.iter().enumerate() {
        for (s, meta) in eval.systems.iter().enumerate() {
            let c = eval.costs[m][s];
            if c.is_feasible() {
                out.push(BenchEntry::new(
                    format!("{prefix}/{}/{}/iter_ms", meta.slug,
                            slug(model.name)),
                    c.total_ms(),
                    "ms",
                ));
            }
        }
    }
    out
}

fn improvement_entry(prefix: &str, eval: &SystemEval) -> BenchEntry {
    BenchEntry::new(
        format!("{prefix}/hulk_improvement_pct"),
        eval.hulk_improvement() * 100.0,
        "%",
    )
}

/// Distinct regions hosting machines of `fleet`.
fn region_count(fleet: &Fleet) -> usize {
    fleet
        .machines
        .iter()
        .map(|m| m.region)
        .collect::<BTreeSet<Region>>()
        .len()
}

/// The shared Fig. 6 scale-out procedure (used by both the `fig6` bench
/// and the `fleet_growth` scenario): drop node 45 from the evaluation
/// fleet, oracle-partition the four-model workload, then join the
/// paper's node `{Rome, 7, 384}`. Returns the grown fleet, the updated
/// assignment, the size-sorted tasks, the joined machine id, the task it
/// joined (None = spare pool), and the pre-join intra-group cost.
pub(crate) fn fig6_scale_out(seed: u64)
    -> (Fleet, Assignment, Vec<ModelSpec>, usize, Option<usize>, f64)
{
    let mut fleet = Fleet::paper_evaluation(seed);
    fleet.remove_machine(45);
    let graph = ClusterGraph::from_fleet(&fleet);
    let mut tasks = ModelSpec::paper_four();
    ModelSpec::sort_largest_first(&mut tasks);
    let mut assignment = oracle_partition(&fleet, &graph, &tasks,
                                          &OracleOptions::default());
    let before_cost = assignment.total_cost(&graph);
    let spec = fig6_node_45();
    let (id, joined) = scale_out(&mut fleet, &mut assignment, &tasks,
                                 spec.region, spec.gpu, spec.n_gpus);
    (fleet, assignment, tasks, id, joined, before_cost)
}

// ----------------------------------------------------- fleet builders --

/// Heterogeneous fleet: 20 servers over five well-connected regions, GPU
/// model and count drawn per machine from the full catalog.
fn hetero_fleet(seed: u64) -> Fleet {
    let regions = [Region::California, Region::Tokyo, Region::Berlin,
                   Region::London, Region::Rome];
    let mut rng = Rng::new(seed ^ 0x4845_5445_524F); // "HETERO"
    let mut machines = Vec::new();
    for i in 0..20 {
        let region = regions[i % regions.len()];
        let gpu = GpuModel::ALL[rng.below(GpuModel::ALL.len())];
        let n_gpus = [4, 8, 8, 12][rng.below(4)];
        machines.push(Machine::new(i, region, gpu, n_gpus));
    }
    Fleet::new(machines, WanModel::new(seed))
}

// ----------------------------------------------------- finish reports --

/// The paper's own evaluation situation (Table 1 WAN + §6.1 fleet).
fn table1_finish(_fleet: &Fleet, eval: &SystemEval)
    -> (Vec<BenchEntry>, String)
{
    let mut entries = eval_entries("table1_fleet", eval);
    entries.push(improvement_entry("table1_fleet", eval));
    let rendered = format!(
        "{}\nHulk improvement over best feasible baseline: {:.1}% \
         (paper claims >20%)\n",
        eval.render(),
        eval.hulk_improvement() * 100.0
    );
    (entries, rendered)
}

fn hetero_finish(fleet: &Fleet, eval: &SystemEval)
    -> (Vec<BenchEntry>, String)
{
    let mut entries = eval_entries("hetero_gpu", eval);
    entries.push(improvement_entry("hetero_gpu", eval));
    entries.push(BenchEntry::new(
        "hetero_gpu/fleet_total_memory_gb",
        fleet.total_memory_gb(),
        "GB",
    ));
    let rendered = format!(
        "fleet: {} servers / {} GPUs / {:.1} TB over {} regions\n{}\n\
         Hulk improvement: {:.1}%\n",
        fleet.len(),
        fleet.total_gpus(),
        fleet.total_memory_gb() / 1e3,
        region_count(fleet),
        eval.render(),
        eval.hulk_improvement() * 100.0
    );
    (entries, rendered)
}

fn planet_finish(fleet: &Fleet, eval: &SystemEval)
    -> (Vec<BenchEntry>, String)
{
    let mut entries = eval_entries("planet_scale", eval);
    entries.push(improvement_entry("planet_scale", eval));
    entries.push(BenchEntry::new("planet_scale/fleet_servers",
                                 fleet.len() as f64, "count"));
    entries.push(BenchEntry::new("planet_scale/fleet_regions",
                                 region_count(fleet) as f64, "count"));
    entries.push(BenchEntry::new(
        "planet_scale/fleet_total_memory_gb",
        fleet.total_memory_gb(),
        "GB",
    ));
    let rendered = format!(
        "planet fleet: {} servers / {} GPUs / {:.1} TB over {} regions\n\
         {}\nHulk improvement over best feasible baseline: {:.1}%\n",
        fleet.len(),
        fleet.total_gpus(),
        fleet.total_memory_gb() / 1e3,
        region_count(fleet),
        eval.render(),
        eval.hulk_improvement() * 100.0
    );
    (entries, rendered)
}

// ------------------------------------------------------------ scenarios --

/// WAN degradation ×1..×8; the ×4 WAN gets the full system comparison.
/// Each factor is evaluated exactly once (no second pass through the
/// sweep for the table).
fn wan_degradation(seed: u64, planners: &PlannerRegistry,
                   backend: CostBackend) -> Result<ScenarioResult>
{
    let workload = ModelSpec::paper_four();
    let mut entries = Vec::new();
    let mut placements = Vec::new();
    let mut t = Table::new(&["factor", "Hulk improvement"]);
    let mut x4_render = String::new();
    for factor in [1.0, 2.0, 4.0, 8.0] {
        let fleet = Fleet::paper_evaluation(seed).with_wan_scaled(factor);
        let eval = evaluate_with_backend(planners, &fleet, &workload,
                                         HulkSplitterKind::Oracle,
                                         backend)?;
        entries.push(BenchEntry::new(
            format!("wan_degradation/x{factor:.0}/hulk_improvement_pct"),
            eval.hulk_improvement() * 100.0,
            "%",
        ));
        t.row(&[format!("×{factor:.0}"),
                format!("{:.1}%", eval.hulk_improvement() * 100.0)]);
        if factor == 4.0 {
            entries.extend(eval_entries("wan_degradation/x4", &eval));
            entries.extend(exec_entries("wan_degradation/x4", &eval));
            placements = placement_entries("wan_degradation/x4", &eval);
            x4_render = format!("{}{}", eval.render(),
                                eval.render_exec());
        }
    }
    let rendered = format!(
        "— improvement vs degradation factor —\n{}\n— all systems on \
         the ×4 WAN —\n{x4_render}",
        t.render()
    );
    Ok(ScenarioResult {
        scenario: "wan_degradation",
        entries,
        placements,
        rendered,
    })
}

/// Fleet growth 12→46 plus the Fig. 6 scale-out join.
fn fleet_growth(seed: u64, planners: &PlannerRegistry,
                backend: CostBackend) -> Result<ScenarioResult>
{
    let workload = ModelSpec::paper_four();
    let sizes = [12usize, 16, 24, 32, 46];
    let points =
        fleet_size_sweep(planners, backend, seed, &sizes, &workload)?;
    let mut entries = Vec::new();
    let mut t = Table::new(&["servers", "Hulk improvement"]);
    for p in &points {
        entries.push(BenchEntry::new(
            format!("fleet_growth/n{:.0}/hulk_improvement_pct", p.x),
            p.improvement * 100.0,
            "%",
        ));
        t.row(&[format!("{:.0}", p.x),
                format!("{:.1}%", p.improvement * 100.0)]);
    }

    // Mid-growth checkpoint: every registered planner on the 24-server
    // fleet.
    let mid = truncated_fleet(&Fleet::paper_evaluation(seed), 24);
    let mid_workload = feasible_workload(&mid, &workload);
    let eval = evaluate_with_backend(planners, &mid, &mid_workload,
                                     HulkSplitterKind::Oracle, backend)?;
    entries.extend(eval_entries("fleet_growth/n24", &eval));
    entries.push(improvement_entry("fleet_growth/n24", &eval));
    entries.extend(exec_entries("fleet_growth/n24", &eval));
    let placements = placement_entries("fleet_growth/n24", &eval);

    // Fig. 6: node 45 {Rome, 7, 384} joins the 45-server system.
    let (fleet46, assignment, tasks, id, joined, _before_cost) =
        fig6_scale_out(seed);
    let graph46 = ClusterGraph::from_fleet(&fleet46);
    assignment
        .validate_disjoint(fleet46.len())
        .map_err(|e| anyhow::anyhow!(e))?;
    assignment
        .validate_memory(&fleet46, &tasks)
        .map_err(|e| anyhow::anyhow!(e))?;
    entries.push(BenchEntry::new(
        "fleet_growth/scale_out/joined_task",
        if joined.is_some() { 1.0 } else { 0.0 },
        "count",
    ));
    entries.push(BenchEntry::new(
        "fleet_growth/scale_out/total_cost",
        assignment.total_cost(&graph46),
        "ms_edges",
    ));
    let rendered = format!(
        "— improvement vs fleet size —\n{}\n— 24-server checkpoint —\n{}\n\
         node {id} {} joined → {}\n",
        t.render(),
        eval.render(),
        fig6_node_45().label(),
        match joined {
            Some(task) => format!("task {task}"),
            None => "spare pool".to_string(),
        }
    );
    Ok(ScenarioResult {
        scenario: "fleet_growth",
        entries,
        placements,
        rendered,
    })
}

/// Five machine failures against the leader's recovery policy, then the
/// registered planners re-evaluated on the surviving fleet, plus a DES
/// run with a mid-iteration failure (when a Hulk planner is registered).
fn failure_storm(seed: u64, planners: &PlannerRegistry,
                 backend: CostBackend) -> Result<ScenarioResult>
{
    let fleet = Fleet::paper_evaluation(seed);
    let mut coordinator = Coordinator::new(fleet.clone());
    for model in ModelSpec::paper_four() {
        coordinator.handle(CoordinatorEvent::Submit { model,
                                                      iterations: 100 });
    }

    let mut rng = Rng::new(seed ^ 0x5354_4F52_4D21); // "STORM!"
    let mut victims: Vec<usize> = Vec::new();
    while victims.len() < 5 {
        let v = rng.below(fleet.len());
        if !victims.contains(&v) {
            victims.push(v);
        }
    }
    // Recovery action histogram, indexed promote/shrink/requeue/noop.
    let mut counts = [0usize; 4];
    for &victim in &victims {
        if let CoordinatorReply::Recovered { action } = coordinator
            .handle(CoordinatorEvent::MachineFailed { machine: victim })
        {
            let idx = match action {
                RecoveryAction::PromoteSpare { .. } => 0,
                RecoveryAction::ShrinkGroup { .. } => 1,
                RecoveryAction::Requeue { .. } => 2,
                RecoveryAction::NoOp => 3,
            };
            counts[idx] += 1;
        }
    }
    let mut entries = Vec::new();
    for (label, &n) in ["promote_spare", "shrink_group", "requeue", "noop"]
        .iter()
        .zip(&counts)
    {
        entries.push(BenchEntry::new(
            format!("failure_storm/recovery/{label}"),
            n as f64,
            "count",
        ));
    }

    // The registered planners on the surviving fleet. Remove victims
    // largest-id first so earlier removals do not shift later ids.
    let mut survivors = fleet.clone();
    let mut doomed = victims.clone();
    doomed.sort_unstable();
    for &victim in doomed.iter().rev() {
        survivors.remove_machine(victim);
    }
    entries.push(BenchEntry::new("failure_storm/survivor_count",
                                 survivors.len() as f64, "count"));
    let workload = feasible_workload(&survivors, &ModelSpec::paper_four());
    // One ScenarioWorld for everything downstream: the shed-retry loop
    // and the DES step used to rebuild the survivors' O(n²) graph per
    // attempt; workload forks share it.
    let mut world = ScenarioWorld::new(survivors, workload);
    // The storm can leave too little contiguous memory for the largest
    // model; deterministically shed largest-first until Algorithm 1
    // accepts (paper: such tasks queue until resources return).
    let eval = loop {
        match evaluate_world(planners, &world, HulkSplitterKind::Oracle,
                             backend) {
            Ok(eval) => break eval,
            Err(_) if world.workload().len() > 1 => {
                world = world.with_workload(world.workload()[1..].to_vec());
            }
            Err(e) => return Err(e),
        }
    };
    entries.extend(eval_entries("failure_storm/survivors", &eval));
    entries.push(improvement_entry("failure_storm/survivors", &eval));
    entries.extend(exec_entries("failure_storm/survivors", &eval));
    let placements = placement_entries("failure_storm/survivors", &eval);

    // DES: interrupt the largest surviving Hulk pipeline mid-iteration.
    // Prefer the registered Hulk system, falling back to a Hulk-family
    // ablation so `--systems hulk_no_gcn,…` runs keep the sim rows;
    // skipped only when the filter leaves no grouping planner at all.
    let des_planner = planners
        .iter()
        .find(|p| p.kind() == PlannerKind::Hulk)
        .or_else(|| {
            planners.iter().find(|p| p.kind() == PlannerKind::Ablation)
        });
    let mut sim_note = String::new();
    let survivors = world.fleet();
    if let Some(hulk) = des_planner {
        let ctx = world.context(HulkSplitterKind::Oracle);
        let placement = hulk.plan(&ctx)?;
        let pipe = placement
            .pipeline(0)
            .expect("hulk-family planners emit pipelined placements");
        if pipe.stages.len() > 1
            && pipeline_cost(survivors, &pipe, &eval.models[0])
                .is_feasible()
        {
            let healthy = simulate_pipeline(survivors, &pipe,
                                            &eval.models[0], false, None);
            entries.push(BenchEntry::new(
                "failure_storm/sim/healthy_makespan_ms",
                healthy.makespan_ms,
                "ms",
            ));
            let injected = FailurePlan {
                at_ms: healthy.makespan_ms * 0.5,
                machine: pipe.stages[1],
            };
            let interrupted = simulate_pipeline(survivors, &pipe,
                                                &eval.models[0], false,
                                                Some(injected));
            if let Some(outcome) = interrupted.failure {
                entries.push(BenchEntry::new(
                    "failure_storm/sim/microbatches_salvaged",
                    outcome.completed_microbatches as f64,
                    "count",
                ));
                sim_note = format!(
                    "DES: stage machine {} killed at {} → {} of {} \
                     microbatches salvaged\n",
                    outcome.machine,
                    fmt_ms(outcome.at_ms),
                    outcome.completed_microbatches,
                    pipe.microbatches
                );
            }
        }
    }

    let rendered = format!(
        "failed machines: {victims:?}\nrecovery actions: promote-spare \
         {} | shrink {} | requeue {} | noop {}\n{}— systems on the {} \
         survivors —\n{}\nHulk improvement: {:.1}%\n",
        counts[0], counts[1], counts[2], counts[3], sim_note,
        survivors.len(),
        eval.render(),
        eval.hulk_improvement() * 100.0
    );
    Ok(ScenarioResult {
        scenario: "failure_storm",
        entries,
        placements,
        rendered,
    })
}

/// Per-model baseline rows on a pristine fleet: each registered baseline
/// planner plans and prices the model alone (their defining weakness in
/// a multi-tenant setting is getting the whole fleet per model).
fn baseline_rows(planners: &PlannerRegistry, fleet: &Fleet,
                 graph: &ClusterGraph, backend: CostBackend, prefix: &str,
                 model: &ModelSpec, entries: &mut Vec<BenchEntry>)
    -> Result<()>
{
    let single = [model.clone()];
    let ctx = PlanContext::new(fleet, graph, &single,
                               HulkSplitterKind::Oracle)
        .with_backend(backend);
    for planner in planners.baselines() {
        let placement = planner.plan(&ctx)?;
        let cost = planner.price(&ctx, &placement).per_task[0];
        if cost.is_feasible() {
            entries.push(BenchEntry::new(
                format!("{prefix}/{}/{}/iter_ms", planner.slug(),
                        slug(model.name)),
                cost.total_ms(),
                "ms",
            ));
        }
    }
    Ok(())
}

/// Six models arriving as a stream through the leader loop, with a
/// mid-stream machine failure; baselines costed on the same arrivals.
/// (The leader's own per-group pricing is analytic by construction; the
/// backend reaches the baseline comparison rows.)
fn multi_tenant(seed: u64, planners: &PlannerRegistry,
                backend: CostBackend) -> Result<ScenarioResult>
{
    let fleet = Fleet::paper_evaluation(seed);
    let mut rng = Rng::new(seed ^ 0x4D54_454E_414E); // "MTENAN"
    let mut arrivals = ModelSpec::paper_six();
    rng.shuffle(&mut arrivals);

    let mut coordinator = Coordinator::new(fleet.clone());
    for (i, model) in arrivals.iter().enumerate() {
        coordinator.handle(CoordinatorEvent::Submit {
            model: model.clone(),
            iterations: 30,
        });
        if i == 2 {
            let victim = rng.below(fleet.len());
            coordinator
                .handle(CoordinatorEvent::MachineFailed { machine: victim });
        }
        coordinator.handle(CoordinatorEvent::Tick { iterations: 10 });
    }
    // Drain: completed tasks free machines for whatever queued.
    for _ in 0..10 {
        coordinator.handle(CoordinatorEvent::Tick { iterations: 30 });
    }

    let mut entries = Vec::new();
    for counter in ["tasks_admitted", "tasks_queued", "machine_failures"] {
        entries.push(BenchEntry::new(
            format!("multi_tenant/{counter}"),
            coordinator.metrics.counter(counter) as f64,
            "count",
        ));
    }
    // Hulk: per-task iteration time on the leader's disjoint groups.
    let mut t = Table::new(&["task", "group size", "iter"]);
    for task in &coordinator.tasks {
        if task.machines.is_empty() {
            continue;
        }
        if let Some(ms) = coordinator.task_iter_ms(task) {
            entries.push(BenchEntry::new(
                format!("multi_tenant/hulk/{}/iter_ms",
                        slug(task.model.name)),
                ms,
                "ms",
            ));
            t.row(&[task.model.name.to_string(),
                    task.machines.len().to_string(), fmt_ms(ms)]);
        }
    }
    // Baselines get the whole (pristine) fleet per model — that is their
    // defining weakness in a multi-tenant setting.
    let graph = ClusterGraph::from_fleet(&fleet);
    for model in &arrivals {
        baseline_rows(planners, &fleet, &graph, backend, "multi_tenant",
                      model, &mut entries)?;
    }

    let arrival_names: Vec<&str> =
        arrivals.iter().map(|m| m.name).collect();
    let rendered = format!(
        "arrival order: {}\nadmitted {} | queued {} | failures {}\n\
         — Hulk groups (leader loop) —\n{}",
        arrival_names.join(" → "),
        coordinator.metrics.counter("tasks_admitted"),
        coordinator.metrics.counter("tasks_queued"),
        coordinator.metrics.counter("machine_failures"),
        t.render()
    );
    Ok(ScenarioResult {
        scenario: "multi_tenant",
        entries,
        placements: Vec::new(),
        rendered,
    })
}

/// Knuth's Poisson sampler: deterministic given the rng stream.
fn poisson(rng: &mut Rng, lambda: f64) -> usize {
    let floor = (-lambda).exp();
    let mut k = 0usize;
    let mut p = 1.0;
    loop {
        p *= rng.f64();
        if p <= floor {
            return k;
        }
        k += 1;
    }
}

/// Poisson-like seeded task bursts through the leader loop: every slot
/// draws `Poisson(λ)` arrivals from the small/mid model catalog, two
/// machines die mid-storm, and the queue drains under a bounded tick
/// budget — so total leader events are bounded regardless of seed.
fn burst_arrivals(seed: u64, planners: &PlannerRegistry,
                  backend: CostBackend) -> Result<ScenarioResult>
{
    const SLOTS: usize = 24;
    const LAMBDA: f64 = 0.75;
    const MAX_DRAIN_TICKS: u64 = 64;
    const FAILURE_SLOTS: [usize; 2] = [8, 16];

    let fleet = Fleet::paper_evaluation(seed);
    let mut rng = Rng::new(seed);
    let catalog = [ModelSpec::t5_11b(), ModelSpec::gpt2_xl(),
                   ModelSpec::bert_large(), ModelSpec::roberta_large(),
                   ModelSpec::xlnet_large()];
    let mut coordinator = Coordinator::new(fleet.clone());
    let mut events: u64 = 0;
    let mut peak_queue: u64 = 0;
    for slot in 0..SLOTS {
        for _ in 0..poisson(&mut rng, LAMBDA) {
            let model = catalog[rng.below(catalog.len())].clone();
            let iterations = 10 + rng.below(20) as u64;
            coordinator.handle(CoordinatorEvent::Submit { model,
                                                          iterations });
            events += 1;
        }
        if FAILURE_SLOTS.contains(&slot) {
            let victim = rng.below(fleet.len());
            coordinator
                .handle(CoordinatorEvent::MachineFailed { machine: victim });
            events += 1;
        }
        coordinator.handle(CoordinatorEvent::Tick { iterations: 5 });
        events += 1;
        let queued = coordinator
            .tasks
            .iter()
            .filter(|t| t.state == TaskState::Queued)
            .count() as u64;
        peak_queue = peak_queue.max(queued);
    }
    // Bounded drain: completed tasks free machines for the queue; stop
    // as soon as nothing is active or queued, or at the tick budget.
    let mut drain_ticks: u64 = 0;
    while drain_ticks < MAX_DRAIN_TICKS
        && coordinator
            .tasks
            .iter()
            .any(|t| t.is_active() || t.state == TaskState::Queued)
    {
        coordinator.handle(CoordinatorEvent::Tick { iterations: 10 });
        events += 1;
        drain_ticks += 1;
    }

    let mut entries = Vec::new();
    for counter in ["tasks_submitted", "tasks_admitted", "tasks_queued",
                    "machine_failures"]
    {
        entries.push(BenchEntry::new(
            format!("burst_arrivals/{counter}"),
            coordinator.metrics.counter(counter) as f64,
            "count",
        ));
    }
    let completed = coordinator
        .tasks
        .iter()
        .filter(|t| t.state == TaskState::Completed)
        .count();
    entries.push(BenchEntry::new("burst_arrivals/tasks_completed",
                                 completed as f64, "count"));
    entries.push(BenchEntry::new("burst_arrivals/events_processed",
                                 events as f64, "count"));
    entries.push(BenchEntry::new("burst_arrivals/peak_queue_depth",
                                 peak_queue as f64, "count"));
    entries.push(BenchEntry::new("burst_arrivals/drain_ticks",
                                 drain_ticks as f64, "count"));

    // Hulk: per-task iteration time on the leader's groups (task ids
    // disambiguate repeated models in the stream).
    let mut t = Table::new(&["task", "model", "group size", "iter"]);
    for task in &coordinator.tasks {
        if task.machines.is_empty() {
            continue;
        }
        if let Some(ms) = coordinator.task_iter_ms(task) {
            entries.push(BenchEntry::new(
                format!("burst_arrivals/hulk/t{}_{}/iter_ms", task.id,
                        slug(task.model.name)),
                ms,
                "ms",
            ));
            t.row(&[task.id.to_string(), task.model.name.to_string(),
                    task.machines.len().to_string(), fmt_ms(ms)]);
        }
    }
    // Baselines on the pristine fleet, one row per distinct model seen.
    let graph = ClusterGraph::from_fleet(&fleet);
    let mut seen: Vec<&'static str> = Vec::new();
    for task in &coordinator.tasks {
        if seen.contains(&task.model.name) {
            continue;
        }
        seen.push(task.model.name);
        baseline_rows(planners, &fleet, &graph, backend, "burst_arrivals",
                      &task.model, &mut entries)?;
    }

    let rendered = format!(
        "{SLOTS} arrival slots (λ = {LAMBDA}), {} submitted | {} \
         admitted | {} queued | {completed} completed | {} failures\n\
         {events} leader events, peak queue {peak_queue}, drained in \
         {drain_ticks} ticks\n— Hulk groups (leader loop) —\n{}",
        coordinator.metrics.counter("tasks_submitted"),
        coordinator.metrics.counter("tasks_admitted"),
        coordinator.metrics.counter("tasks_queued"),
        coordinator.metrics.counter("machine_failures"),
        t.render()
    );
    Ok(ScenarioResult {
        scenario: "burst_arrivals",
        entries,
        placements: Vec::new(),
        rendered,
    })
}

/// The two-region contention fleet: twelve A100 servers split evenly
/// between Beijing and California, so **every** cross-region byte of
/// every task crosses the same trans-Pacific link.
fn pacific_fleet(seed: u64) -> Fleet {
    let machines: Vec<Machine> = (0..12)
        .map(|i| {
            let region = if i < 6 { Region::Beijing }
                         else { Region::California };
            Machine::new(i, region, GpuModel::A100, 8)
        })
        .collect();
    Fleet::new(machines, WanModel::new(seed))
}

/// Five models training concurrently on the two-region fleet. Only the
/// discrete-event backend can see the story here: System B's id-order
/// pipelines all straddle the Pacific and queue on the one shared link,
/// while Hulk's regional groups barely touch it. The incoming backend is
/// ignored — contention *is* the subject, so pricing is pinned to the
/// simulator ([`resolve_scenarios`] only admits this scenario under
/// `--cost sim` anyway).
fn contended_links(seed: u64, planners: &PlannerRegistry,
                   _backend: CostBackend) -> Result<ScenarioResult>
{
    let fleet = pacific_fleet(seed);
    let workload = vec![ModelSpec::t5_11b(), ModelSpec::gpt2_xl(),
                        ModelSpec::roberta_large(), ModelSpec::bert_large(),
                        ModelSpec::xlnet_large()];
    let eval = evaluate_with_backend(planners, &fleet, &workload,
                                     HulkSplitterKind::Oracle,
                                     CostBackend::Simulated)?;
    let mut entries = eval_entries("contended_links", &eval);
    entries.push(improvement_entry("contended_links", &eval));
    entries.extend(exec_entries("contended_links", &eval));
    // The trans-Pacific link, per system: the scenario's headline row.
    let mut t = Table::new(&["System", "pacific busy", "utilization"]);
    for (meta, exec) in eval.systems.iter().zip(&eval.exec) {
        let Some(exec) = exec else { continue };
        let pacific = exec
            .links
            .iter()
            .find(|l| l.connects(Region::Beijing, Region::California));
        let (busy, util) = pacific
            .map(|l| (l.busy_ms, l.utilization))
            .unwrap_or((0.0, 0.0));
        entries.push(BenchEntry::new(
            format!("contended_links/{}/sim/pacific_utilization_pct",
                    meta.slug),
            util * 100.0,
            "%",
        ));
        t.row(&[meta.name.to_string(), fmt_ms(busy),
                format!("{:.0}%", util * 100.0)]);
    }
    let placements = placement_entries("contended_links", &eval);
    let rendered = format!(
        "two-region fleet: 6 Beijing + 6 California A100 servers, one \
         shared trans-Pacific link, {} concurrent tasks\n{}{}\
         — trans-Pacific link —\n{}\nHulk improvement under contention: \
         {:.1}%\n",
        eval.models.len(),
        eval.render(),
        eval.render_exec(),
        t.render(),
        eval.hulk_improvement() * 100.0
    );
    Ok(ScenarioResult {
        scenario: "contended_links",
        entries,
        placements,
        rendered,
    })
}

/// The same Table 1 fleet and workload priced by both backends: reports
/// the per-system gap between closed-form pricing and contended
/// execution, and whether the system *ranking* survives. The incoming
/// backend is ignored — comparing the two backends is the scenario.
fn sim_vs_analytic(seed: u64, planners: &PlannerRegistry,
                   _backend: CostBackend) -> Result<ScenarioResult>
{
    // One world, priced by both backends — the fleet/graph/workload are
    // identical by construction, so building them twice would only
    // duplicate the O(n²) setup.
    let world = ScenarioWorld::new(Fleet::paper_evaluation(seed),
                                   ModelSpec::paper_four());
    let analytic = evaluate_world(planners, &world,
                                  HulkSplitterKind::Oracle,
                                  CostBackend::Analytic)?;
    let sim = evaluate_world(planners, &world, HulkSplitterKind::Oracle,
                             CostBackend::Simulated)?;
    let mut entries = Vec::new();
    let mut t = Table::new(&["System", "analytic Σ", "sim Σ", "gap"]);
    for (s, meta) in analytic.systems.iter().enumerate() {
        let total = |eval: &SystemEval| -> f64 {
            eval.costs
                .iter()
                .map(|row| row[s])
                .filter(IterCost::is_feasible)
                .map(|c| c.total_ms())
                .sum()
        };
        let a_total = total(&analytic);
        let s_total = total(&sim);
        entries.push(BenchEntry::new(
            format!("sim_vs_analytic/{}/analytic_total_ms", meta.slug),
            a_total,
            "ms",
        ));
        entries.push(BenchEntry::new(
            format!("sim_vs_analytic/{}/sim_total_ms", meta.slug),
            s_total,
            "ms",
        ));
        let gap = if a_total > 0.0 { s_total / a_total } else { 0.0 };
        entries.push(BenchEntry::new(
            format!("sim_vs_analytic/{}/contention_gap_x", meta.slug),
            gap,
            "x",
        ));
        t.row(&[meta.name.to_string(), fmt_ms(a_total), fmt_ms(s_total),
                format!("{gap:.2}×")]);
    }
    entries.extend(exec_entries("sim_vs_analytic", &sim));
    // Does the per-model winner agree between the backends?
    let winner = |eval: &SystemEval, m: usize| -> usize {
        (0..eval.systems.len())
            .min_by(|&x, &y| {
                eval.costs[m][x]
                    .total_ms()
                    .total_cmp(&eval.costs[m][y].total_ms())
            })
            .expect("non-empty registry")
    };
    let agreements = (0..analytic.models.len())
        .filter(|&m| winner(&analytic, m) == winner(&sim, m))
        .count();
    entries.push(BenchEntry::new(
        "sim_vs_analytic/ranking_agreements",
        agreements as f64,
        "count",
    ));
    let placements = placement_entries("sim_vs_analytic", &sim);
    let rendered = format!(
        "— analytic vs contended execution (Table 1 fleet) —\n{}{}\
         per-model winner agreement: {agreements}/{} models\n",
        t.render(),
        sim.render_exec(),
        analytic.models.len()
    );
    Ok(ScenarioResult {
        scenario: "sim_vs_analytic",
        entries,
        placements,
        rendered,
    })
}

/// `generated_sweep` — the property engine as a benchmark scenario:
/// scan generated cases from the scenario seed, price the first
/// `SWEEP_CASES` that every registered planner fully plans with zero
/// property violations, and report per-case per-system totals plus
/// aggregate counters (`violations` must stay 0). Sim-only: the
/// property checks themselves exercise the discrete-event backend
/// (winner agreement), and keeping the sweep off the analytic path
/// leaves the default `BENCH_scenarios.json` byte-identical.
fn generated_sweep(seed: u64, planners: &PlannerRegistry,
                   _backend: CostBackend) -> Result<ScenarioResult>
{
    const SWEEP_CASES: usize = 6;
    const SWEEP_SCAN: usize = 24;
    let opts = CheckOptions::default();
    let mut entries = Vec::new();
    let mut placements = Vec::new();
    let mut t = Table::new(&["case", "shape", "hulk Δ"]);
    let mut priced = 0usize;
    let mut declined = 0usize;
    let mut violations = 0usize;
    let mut improvements: Vec<f64> = Vec::new();
    for index in 0..SWEEP_SCAN {
        if priced == SWEEP_CASES {
            break;
        }
        let case = generate_case(seed, index);
        let report = check_case(&case, planners, &opts);
        violations += report.violations.len();
        if !report.fully_planned || !report.violations.is_empty() {
            declined += usize::from(!report.fully_planned);
            continue;
        }
        let world = ScenarioWorld::new(case.fleet.clone(),
                                       case.workload.clone());
        let eval = evaluate_world(planners, &world,
                                  HulkSplitterKind::Oracle,
                                  CostBackend::Analytic)?;
        for (s, meta) in eval.systems.iter().enumerate() {
            let total: f64 = eval
                .costs
                .iter()
                .map(|row| row[s])
                .filter(IterCost::is_feasible)
                .map(|c| c.total_ms())
                .sum();
            entries.push(BenchEntry::new(
                format!("generated_sweep/case{index:02}/{}/total_ms",
                        meta.slug),
                total,
                "ms",
            ));
        }
        let imp = eval.hulk_improvement() * 100.0;
        entries.push(BenchEntry::new(
            format!("generated_sweep/case{index:02}\
                     /hulk_improvement_pct"),
            imp,
            "%",
        ));
        improvements.push(imp);
        if placements.is_empty() {
            // One representative digest set; per-case digests would
            // dwarf the hand-written scenarios' artifact.
            placements = placement_entries("generated_sweep", &eval);
        }
        t.row(&[format!("{index:02}"), case.shape().to_string(),
                format!("{imp:+.1}%")]);
        priced += 1;
    }
    let mean_imp = if improvements.is_empty() {
        0.0
    } else {
        improvements.iter().sum::<f64>() / improvements.len() as f64
    };
    entries.push(BenchEntry::new("generated_sweep/cases_priced",
                                 priced as f64, "count"));
    entries.push(BenchEntry::new("generated_sweep/cases_declined",
                                 declined as f64, "count"));
    entries.push(BenchEntry::new("generated_sweep/violations",
                                 violations as f64, "count"));
    entries.push(BenchEntry::new("generated_sweep/hulk_improvement_pct",
                                 mean_imp, "%"));
    let rendered = format!(
        "— generated property sweep (seed {seed}) —\n{}\
         {priced} case(s) priced, {declined} declined, \
         {violations} property violations\n",
        t.render()
    );
    Ok(ScenarioResult {
        scenario: "generated_sweep",
        entries,
        placements,
        rendered,
    })
}

/// Shared body of the heavy scale scenarios (`continent_scale`,
/// `global_scale`): a synthetic `n_servers`-machine fleet over all 12
/// regions is planned region-first through the [`HierarchicalGraph`] —
/// past `HIER_THRESHOLD` the fine level stays lazy, so the dense n×n
/// adjacency is never materialized. Only Hulk-family planners run (the
/// baselines are all-pairs strategies that would densify by design);
/// every entry is a deterministic placement digest — wall-clock scaling
/// is `bench micro`'s job, not a scenario artifact's.
fn scale_scenario(name: &'static str, n_servers: usize, seed: u64,
                  planners: &PlannerRegistry, fail_one: bool)
    -> Result<ScenarioResult>
{
    let fleet = Arc::new(Fleet::synthetic(n_servers, 12, seed));
    let mut hier = HierarchicalGraph::from_fleet(fleet.clone());
    anyhow::ensure!(
        hier.is_coarse(),
        "{name} exists to exercise region-first planning; {n_servers} \
         servers must exceed HIER_THRESHOLD"
    );
    let mut workload = ModelSpec::paper_four();
    ModelSpec::sort_largest_first(&mut workload);

    let family: Vec<_> = planners
        .iter()
        .filter(|p| p.kind() != PlannerKind::Baseline)
        .collect();
    anyhow::ensure!(
        !family.is_empty(),
        "{name} needs a Hulk-family planner; the baselines are \
         all-pairs strategies that cannot run at {n_servers} servers"
    );

    let mut entries = vec![
        BenchEntry::new(format!("{name}/fleet_servers"),
                        fleet.len() as f64, "count"),
        BenchEntry::new(format!("{name}/fleet_regions"),
                        region_count(&fleet) as f64, "count"),
        BenchEntry::new(format!("{name}/fleet_total_memory_gb"),
                        fleet.total_memory_gb(), "GB"),
    ];
    let mut placements = Vec::new();
    let mut t = Table::new(&["planner", "model", "group", "iter"]);
    let mut first_groups: Vec<Vec<usize>> = Vec::new();
    for planner in &family {
        let ctx = PlanContext::new(&fleet, &hier, &workload,
                                   HulkSplitterKind::Oracle)
            .with_hier(&hier);
        let placement = planner.plan(&ctx)?;
        placement
            .validate_machines(&fleet)
            .map_err(|e| anyhow::anyhow!(e))?;
        let a = placement.to_assignment();
        a.validate_disjoint(fleet.len()).map_err(|e| anyhow::anyhow!(e))?;
        a.validate_memory(&fleet, &workload)
            .map_err(|e| anyhow::anyhow!(e))?;
        let summary = placement.summary(&fleet);
        let prefix = format!("{name}/{}/placement", planner.slug());
        placements.push(BenchEntry::new(format!("{prefix}/group_count"),
                                        summary.groups as f64, "count"));
        placements.push(BenchEntry::new(format!("{prefix}/stage_count"),
                                        summary.stages as f64, "count"));
        placements.push(BenchEntry::new(
            format!("{prefix}/cross_region_edges"),
            summary.cross_region_edges as f64,
            "count",
        ));
        for (ti, model) in workload.iter().enumerate() {
            let cost = planner.cost(&ctx, &placement, ti);
            entries.push(BenchEntry::new(
                format!("{name}/{}/{}/group_size", planner.slug(),
                        slug(model.name)),
                placement.machines(ti).len() as f64,
                "count",
            ));
            if cost.is_feasible() {
                entries.push(BenchEntry::new(
                    format!("{name}/{}/{}/iter_ms", planner.slug(),
                            slug(model.name)),
                    cost.total_ms(),
                    "ms",
                ));
            }
            t.row(&[planner.slug().to_string(), model.name.to_string(),
                    placement.machines(ti).len().to_string(),
                    if cost.is_feasible() { fmt_ms(cost.total_ms()) }
                    else { "infeasible".to_string() }]);
        }
        if first_groups.is_empty() {
            first_groups = (0..placement.n_tasks())
                .map(|ti| placement.machines(ti).to_vec())
                .collect();
        }
    }

    // Incremental delta: kill one planned machine, let the graph apply
    // the failure in place (summaries + coarse rebuild, no fine-level
    // rework), and replan — the victim must vanish from the placement.
    let mut replan_note = String::new();
    if fail_one {
        let victim = first_groups
            .iter()
            .max_by_key(|g| g.len())
            .and_then(|g| g.first())
            .copied()
            .expect("a planned group is never empty");
        hier.apply_failure(victim);
        let planner = family[0];
        let ctx = PlanContext::new(&fleet, &hier, &workload,
                                   HulkSplitterKind::Oracle)
            .with_hier(&hier);
        let replanned = planner.plan(&ctx)?;
        anyhow::ensure!(
            (0..replanned.n_tasks())
                .all(|ti| !replanned.machines(ti).contains(&victim)),
            "machine {victim} failed but was placed again"
        );
        let summary = replanned.summary(&fleet);
        entries.push(BenchEntry::new(format!("{name}/replan/victim"),
                                     victim as f64, "count"));
        entries.push(BenchEntry::new(
            format!("{name}/replan/group_count"),
            summary.groups as f64,
            "count",
        ));
        replan_note = format!(
            "machine {victim} failed → {} replanned {} groups without \
             touching the dense path\n",
            planner.slug(),
            summary.groups
        );
    }

    let rendered = format!(
        "{name}: {} servers / {} regions / {:.1} TB, planned \
         region-first over the {}-node coarse graph\n{}{replan_note}",
        fleet.len(),
        region_count(&fleet),
        fleet.total_memory_gb() / 1e3,
        hier.coarse().n,
        t.render()
    );
    Ok(ScenarioResult { scenario: name, entries, placements, rendered })
}

/// 10k servers planned through the hierarchical substrate.
fn continent_scale(seed: u64, planners: &PlannerRegistry,
                   _backend: CostBackend) -> Result<ScenarioResult>
{
    scale_scenario("continent_scale", 10_000, seed, planners, false)
}

/// 100k servers: hierarchical planning plus an incremental
/// failure-delta replan.
fn global_scale(seed: u64, planners: &PlannerRegistry,
                _backend: CostBackend) -> Result<ScenarioResult>
{
    scale_scenario("global_scale", 100_000, seed, planners, true)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenarios::evaluate::evaluate_all;

    #[test]
    fn slugs_compress_model_names() {
        assert_eq!(slug("OPT (175B)"), "opt_175b");
        assert_eq!(slug("GPT-2 (1.5B)"), "gpt_2_1_5b");
        assert_eq!(slug("System A (DP)"), "system_a_dp");
        assert_eq!(slug("___"), "");
    }

    #[test]
    fn registry_is_populated_with_unique_names() {
        let scenarios = all_scenarios();
        assert!(scenarios.len() >= 13);
        let mut names: Vec<&str> =
            scenarios.iter().map(|s| s.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), scenarios.len());
        assert!(find_scenario("table1_fleet").is_some());
        assert!(find_scenario("planet_scale").is_some());
        assert!(find_scenario("burst_arrivals").is_some());
        assert!(find_scenario("contended_links").is_some());
        assert!(find_scenario("sim_vs_analytic").is_some());
        assert!(find_scenario("no_such_scenario").is_none());
        assert!(find_scenario("generated_sweep").is_some());
        // Exactly the contention studies and the generated property
        // sweep are sim-only.
        let sim_only: Vec<&str> = scenarios
            .iter()
            .filter(|s| s.sim_only)
            .map(|s| s.name)
            .collect();
        assert_eq!(sim_only,
                   vec!["contended_links", "sim_vs_analytic",
                        "generated_sweep"]);
        // Exactly the scale studies are heavy (and never sim-only —
        // they must stay runnable by name under the default backend).
        let heavy: Vec<&str> = scenarios
            .iter()
            .filter(|s| s.heavy)
            .map(|s| s.name)
            .collect();
        assert_eq!(heavy, vec!["continent_scale", "global_scale"]);
        assert!(scenarios.iter().all(|s| !(s.heavy && s.sim_only)));
    }

    #[test]
    fn resolve_rejects_unknown_names_with_the_valid_list() {
        let err = resolve_scenarios(&["bogus".to_string()],
                                    CostBackend::Analytic)
            .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("bogus"), "{msg}");
        for s in all_scenarios() {
            assert!(msg.contains(s.name), "{msg} missing {}", s.name);
        }
        // Unknown names are rejected even when `all` rides along — no
        // silent success path for typos.
        let err = resolve_scenarios(&["all".to_string(),
                                      "bogus".to_string()],
                                    CostBackend::Analytic)
            .unwrap_err();
        assert!(err.to_string().contains("bogus"));
    }

    #[test]
    fn resolve_selects_all_or_subset_per_backend() {
        // Analytic `all` excludes the sim-only contention studies, so
        // the default artifact keeps its historical shape.
        let (specs, ran_all) =
            resolve_scenarios(&[], CostBackend::Analytic).unwrap();
        assert!(ran_all);
        assert_eq!(specs.len(), all_scenarios().len() - 5);
        assert!(specs.iter().all(|s| !s.sim_only && !s.heavy));
        let (specs, ran_all) = resolve_scenarios(&["all".to_string()],
                                                 CostBackend::Analytic)
            .unwrap();
        assert!(ran_all);
        assert_eq!(specs.len(), all_scenarios().len() - 5);
        // The simulated backend runs the complete registry minus the
        // heavy scale studies (those only ever run by name).
        let (specs, ran_all) =
            resolve_scenarios(&[], CostBackend::Simulated).unwrap();
        assert!(ran_all);
        assert_eq!(specs.len(), all_scenarios().len() - 2);
        assert!(specs.iter().all(|s| !s.heavy));
        // Subsets keep the user's order.
        let names = vec!["hetero_gpu".to_string(),
                         "table1_fleet".to_string()];
        let (specs, ran_all) =
            resolve_scenarios(&names, CostBackend::Analytic).unwrap();
        assert!(!ran_all);
        let picked: Vec<&str> = specs.iter().map(|s| s.name).collect();
        assert_eq!(picked, vec!["hetero_gpu", "table1_fleet"]);
    }

    #[test]
    fn sim_only_scenarios_demand_the_sim_backend() {
        let err = resolve_scenarios(&["contended_links".to_string()],
                                    CostBackend::Analytic)
            .unwrap_err();
        assert!(err.to_string().contains("--cost sim"), "{err}");
        let (specs, _) =
            resolve_scenarios(&["sim_vs_analytic".to_string(),
                                "contended_links".to_string()],
                              CostBackend::Simulated)
                .unwrap();
        assert_eq!(specs.len(), 2);
    }

    #[test]
    fn contended_links_shows_the_pacific_bottleneck() {
        let planners = PlannerRegistry::standard();
        let result = find_scenario("contended_links")
            .unwrap()
            .run_with_backend(0, &planners, CostBackend::Simulated)
            .unwrap();
        let get = |name: &str| -> Option<f64> {
            result
                .entries
                .iter()
                .find(|e| e.name == name)
                .map(|e| e.value)
        };
        // System B's id-order pipelines straddle the Pacific for every
        // task; Hulk's regional grouping barely touches it.
        let b = get("contended_links/system_b/sim/pacific_utilization_pct")
            .expect("system_b pacific row");
        let hulk =
            get("contended_links/hulk/sim/pacific_utilization_pct")
                .expect("hulk pacific row");
        assert!(b > hulk, "pacific util: B {b}% vs Hulk {hulk}%");
        let improvement =
            get("contended_links/hulk_improvement_pct").unwrap();
        assert!(improvement > 0.0,
                "Hulk loses under contention: {improvement}%");
        // Deterministic across repeat runs.
        let again = find_scenario("contended_links")
            .unwrap()
            .run_with_backend(0, &planners, CostBackend::Simulated)
            .unwrap();
        let rows = |r: &ScenarioResult| -> Vec<(String, f64)> {
            r.entries.iter().map(|e| (e.name.clone(), e.value)).collect()
        };
        assert_eq!(rows(&result), rows(&again));
    }

    #[test]
    fn sim_vs_analytic_reports_gaps_and_ranking_agreement() {
        let planners = PlannerRegistry::standard();
        let result = find_scenario("sim_vs_analytic")
            .unwrap()
            .run_with_backend(0, &planners, CostBackend::Simulated)
            .unwrap();
        let gap = |slug: &str| -> f64 {
            result
                .entries
                .iter()
                .find(|e| {
                    e.name
                        == format!("sim_vs_analytic/{slug}/contention_gap_x")
                })
                .unwrap_or_else(|| panic!("no gap row for {slug}"))
                .value
        };
        // Systems A and C lower to the exact closed form when alone, so
        // cross-task contention can only push them ABOVE 1 — and on the
        // table1 workload their tasks genuinely overlap.
        assert!(gap("system_a") > 1.0, "A gap {}", gap("system_a"));
        assert!(gap("system_c") > 1.0, "C gap {}", gap("system_c"));
        // Hulk: disjoint groups — no cross-task contention, so the gap
        // is just the GPipe execution-vs-formula factor.
        assert!(gap("hulk") > 0.2 && gap("hulk") < 5.0,
                "hulk gap {}", gap("hulk"));
        // System B's analytic model serializes all boundary traffic
        // (2KΣ) while execution overlaps distinct links, so its gap may
        // legitimately land below 1; only sanity is asserted.
        assert!(gap("system_b").is_finite() && gap("system_b") > 0.0,
                "B gap {}", gap("system_b"));
        let agreements = result
            .entries
            .iter()
            .find(|e| e.name == "sim_vs_analytic/ranking_agreements")
            .expect("agreement row");
        // Hulk wins every model under both backends on the Table 1
        // fleet, so the winner agrees on every row.
        assert_eq!(agreements.value, 4.0);
    }

    #[test]
    fn fig6_helper_produces_valid_assignment() {
        let (fleet, assignment, tasks, id, _joined, before_cost) =
            fig6_scale_out(0);
        assert_eq!(id, 45);
        assert_eq!(fleet.len(), 46);
        assert!(before_cost > 0.0);
        assignment.validate_disjoint(fleet.len()).unwrap();
        assignment.validate_memory(&fleet, &tasks).unwrap();
    }

    #[test]
    fn eval_entries_skip_infeasible_cells() {
        let fleet = Fleet::paper_evaluation(0);
        let eval = evaluate_all(&fleet, &ModelSpec::paper_four(),
                                HulkSplitterKind::Oracle)
            .unwrap();
        let entries = eval_entries("x", &eval);
        // System A × OPT-175B is infeasible → no row for it.
        assert!(entries
            .iter()
            .all(|e| e.name != "x/system_a/opt_175b/iter_ms"));
        assert!(entries
            .iter()
            .any(|e| e.name == "x/hulk/opt_175b/iter_ms"));
        assert!(entries.iter().all(|e| e.value.is_finite()));
    }

    #[test]
    fn custom_scenarios_honor_a_filtered_registry() {
        // multi_tenant with only System B as baseline: no system_a or
        // system_c rows, system_b rows present.
        let planners = PlannerRegistry::resolve("b,hulk").unwrap();
        let result = find_scenario("multi_tenant")
            .unwrap()
            .run_with(0, &planners)
            .unwrap();
        assert!(result.entries.iter().any(|e| e.name.contains("/system_b/")));
        assert!(!result.entries.iter().any(|e| e.name.contains("/system_a/")));
        assert!(!result.entries.iter().any(|e| e.name.contains("/system_c/")));
    }

    #[test]
    fn continent_scale_plans_region_first_and_never_densifies() {
        let planners = PlannerRegistry::standard();
        let spec = find_scenario("continent_scale").unwrap();
        assert!(spec.heavy);
        let result = spec.run_with(7, &planners).unwrap();
        let get = |name: &str| -> Option<f64> {
            result
                .entries
                .iter()
                .find(|e| e.name == name)
                .map(|e| e.value)
        };
        assert_eq!(get("continent_scale/fleet_servers"), Some(10_000.0));
        assert_eq!(get("continent_scale/fleet_regions"), Some(12.0));
        // The big model got a real group, priced feasibly.
        assert!(get("continent_scale/hulk/opt_175b/group_size")
                    .expect("group size row") >= 2.0);
        assert!(get("continent_scale/hulk/opt_175b/iter_ms").is_some());
        assert!(result.placements.iter().any(|e| {
            e.name == "continent_scale/hulk/placement/group_count"
        }));
        // Deterministic, and the whole run stayed off the dense path.
        let again = find_scenario("continent_scale")
            .unwrap()
            .run_with(7, &planners)
            .unwrap();
        let rows = |r: &ScenarioResult| -> Vec<(String, f64)> {
            r.entries.iter().map(|e| (e.name.clone(), e.value)).collect()
        };
        assert_eq!(rows(&result), rows(&again));
        assert!(crate::graph::max_dense_n()
                    <= crate::graph::DENSE_ORACLE_MAX);
    }

    #[test]
    fn poisson_sampler_is_deterministic_and_plausible() {
        let mut a = Rng::new(11);
        let mut b = Rng::new(11);
        let draws_a: Vec<usize> =
            (0..64).map(|_| poisson(&mut a, 0.75)).collect();
        let draws_b: Vec<usize> =
            (0..64).map(|_| poisson(&mut b, 0.75)).collect();
        assert_eq!(draws_a, draws_b);
        let mean = draws_a.iter().sum::<usize>() as f64 / 64.0;
        assert!((0.3..1.5).contains(&mean), "mean {mean}");
    }
}
