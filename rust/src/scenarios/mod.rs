//! The scenario + benchmark subsystem: one place that runs the paper's
//! four systems over many fleet/WAN situations and reports the results,
//! both human-readable (CLI tables) and machine-readable
//! (`BENCH_*.json` via `benchkit`).
//!
//! - [`registry`] — the named-scenario registry (`hulk scenarios`):
//!   deterministic seed→result runners for the Table 1 fleet, WAN
//!   degradation, heterogeneous GPUs, fleet growth, failure storms and
//!   multi-tenant streaming arrivals.
//! - [`evaluate`] — a workload through Systems A/B/C/Hulk (the Fig. 8 /
//!   Fig. 10 rows); the primitive every scenario builds on.
//! - [`sweep`] — parameter sweeps (fleet size, microbatches, WAN
//!   degradation) used by scenarios and `hulk bench sweep`.
//! - [`bench`] — the per-table/figure reproduction entry points
//!   (`hulk bench`, `cargo bench`).
//!
//! `crate::systems` re-exports the evaluation/sweep names that lived
//! there before this subsystem existed.

pub mod bench;
pub mod evaluate;
pub mod registry;
pub mod sweep;

pub use evaluate::{evaluate_all, SystemEval, SystemKind};
pub use registry::{all_scenarios, find_scenario, run_all, Scenario,
                   ScenarioResult};
pub use sweep::{feasible_workload, fleet_size_sweep, microbatch_sweep,
                truncated_fleet, wan_degradation_sweep, SweepPoint};
