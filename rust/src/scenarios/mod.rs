//! The scenario + benchmark subsystem: one place that runs the paper's
//! four systems over many fleet/WAN situations and reports the results,
//! both human-readable (CLI tables) and machine-readable
//! (`BENCH_*.json` via `benchkit`).
//!
//! - [`registry`] — the named-scenario registry (`hulk scenarios`):
//!   deterministic seed→result definitions for the Table 1 fleet, WAN
//!   degradation, heterogeneous GPUs, fleet growth, failure storms,
//!   multi-tenant streaming arrivals, planet-scale synthetic fleets and
//!   bursty Poisson task streams.
//! - [`runner`] — the execution engine: scenario specs decompose into
//!   (scenario × system) cells executed serially or across a std-thread
//!   worker pool, with insertion-ordered merging so `--parallel` output
//!   is byte-identical to a serial run.
//! - [`evaluate`] — a workload through Systems A/B/C/Hulk (the Fig. 8 /
//!   Fig. 10 rows); the primitive every scenario builds on.
//! - [`sweep`] — parameter sweeps (fleet size, microbatches, WAN
//!   degradation) used by scenarios and `hulk bench sweep`.
//! - [`bench`] — the per-table/figure reproduction entry points
//!   (`hulk bench`, `cargo bench`).
//!
//! `crate::systems` re-exports the evaluation/sweep names that lived
//! there before this subsystem existed.

pub mod bench;
pub mod evaluate;
pub mod registry;
pub mod runner;
pub mod sweep;

pub use evaluate::{evaluate_all, SystemEval, SystemKind};
pub use registry::{all_scenarios, find_scenario, resolve_scenarios,
                   run_all};
pub use runner::{run_specs, ScenarioBody, ScenarioResult, ScenarioSpec,
                 SeedPolicy};
pub use sweep::{feasible_workload, fleet_size_sweep, microbatch_sweep,
                truncated_fleet, wan_degradation_sweep, SweepPoint};
