//! The scenario + benchmark subsystem: one place that runs the
//! registered planners (the paper's four systems by default) over many
//! fleet/WAN situations and reports the results, both human-readable
//! (CLI tables) and machine-readable (`BENCH_*.json` via `benchkit`).
//!
//! - [`registry`] — the named-scenario registry (`hulk scenarios`):
//!   deterministic seed→result definitions for the Table 1 fleet, WAN
//!   degradation, heterogeneous GPUs, fleet growth, failure storms,
//!   multi-tenant streaming arrivals, planet-scale synthetic fleets and
//!   bursty Poisson task streams.
//! - [`runner`] — the execution engine: scenario specs decompose into
//!   (scenario × registered planner) cells executed serially or across a
//!   std-thread worker pool, with insertion-ordered merging so
//!   `--parallel` output is byte-identical to a serial run.
//! - [`generator`] — the seeded `(Fleet, Workload, failure script)`
//!   case generator and property-checking engine behind
//!   `hulk scenarios generate --check`, the `generated_sweep`
//!   scenario and `rust/tests/planner_properties.rs`, with
//!   shrinking-on-failure down to a minimal seed+shape repro.
//! - [`evaluate`] — a workload through every planner of a
//!   [`PlannerRegistry`](crate::planner::PlannerRegistry) (the Fig. 8 /
//!   Fig. 10 rows); the primitive every scenario builds on.
//! - [`sweep`] — parameter sweeps (fleet size, microbatches, WAN
//!   degradation) used by scenarios and `hulk bench sweep`.
//! - [`bench`] — the per-table/figure reproduction entry points
//!   (`hulk bench`, `cargo bench`).
//!
//! Which strategies run is decided by the planner registry
//! ([`crate::planner`]): the CLI's `--systems a,b,hulk` filter selects a
//! subset, ablations like `hulk_no_gcn` opt in the same way, and no code
//! here names an individual system.

pub mod bench;
pub mod evaluate;
pub mod generator;
pub mod registry;
pub mod runner;
pub mod sweep;
pub mod world;

pub use evaluate::{evaluate_all, evaluate_with, evaluate_with_backend,
                   evaluate_world, SystemEval};
pub use generator::{check_case, check_generator_determinism,
                    exhaustive_best, generate_case, run_generated,
                    sample_failure_wave, sample_workload, shrink_case,
                    shrink_report, CaseReport, CheckOptions, GenCase,
                    GenShape, GeneratedRun, Violation};
pub use registry::{all_scenarios, find_scenario, resolve_scenarios,
                   run_all};
pub use runner::{run_specs, run_specs_sharing, ScenarioBody,
                 ScenarioResult, ScenarioSpec, SeedPolicy, WorldSharing};
pub use sweep::{feasible_workload, fleet_size_sweep, microbatch_sweep,
                truncated_fleet, wan_degradation_sweep, SweepPoint};
pub use world::{PaddedWorld, ScenarioWorld};
