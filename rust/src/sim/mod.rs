//! Discrete-event simulation of distributed training execution.
//!
//! The analytic cost models in `parallel::` give closed-form per-iteration
//! times; this module *executes* the schedules event-by-event so that
//! (a) the analytic models can be cross-validated (ablation bench),
//! (b) failures can be injected mid-iteration (disaster recovery, §1),
//! (c) traces can be inspected for utilization/bubble analysis.
//!
//! - [`engine`] — generic event queue + clock.
//! - [`pipeline_sim`] — GPipe schedule execution over WAN links with
//!   per-link serialization.
//! - [`failure`] — failure injection plans and outcomes.
//! - [`trace`] — event traces + utilization summaries.

pub mod allreduce_sim;
pub mod engine;
pub mod failure;
pub mod pipeline_sim;
pub mod trace;

pub use allreduce_sim::{simulate_ring_allreduce, AllReduceSimResult};
pub use engine::{Engine, Event};
pub use failure::{FailureOutcome, FailurePlan};
pub use pipeline_sim::{simulate_pipeline, PipelineSimResult};
pub use trace::{Trace, TraceEvent};
