//! Discrete-event simulation of distributed training execution.
//!
//! The analytic cost models in `parallel::` give closed-form per-iteration
//! times; this module *executes* the schedules event-by-event so that
//! (a) the analytic models can be cross-validated (ablation bench),
//! (b) failures can be injected mid-iteration (disaster recovery, §1),
//! (c) traces can be inspected for utilization/bubble analysis,
//! (d) whole placements can be **priced by execution** with shared
//!     WAN-link and machine contention — the `--cost sim` backend
//!     ([`crate::planner::CostBackend`]).
//!
//! - [`engine`] — generic event queue + clock + shared [`Resource`]s.
//! - [`cluster`] — the unified whole-placement executor: every
//!   `TaskPlacement` variant lowered onto shared inter-region links and
//!   machines (contention semantics in the module docs).
//! - [`pipeline_sim`] — thin lowering: one GPipe schedule alone.
//! - [`allreduce_sim`] — thin lowering: one ring all-reduce alone, with
//!   per-link completions in the trace.
//! - [`failure`] — failure injection plans and outcomes.
//! - [`trace`] — event traces + utilization summaries.
//!
//! [`Resource`]: engine::Resource

pub mod allreduce_sim;
pub mod cluster;
pub mod engine;
pub mod failure;
pub mod pipeline_sim;
pub mod trace;

pub use allreduce_sim::{simulate_ring_allreduce, AllReduceSimResult};
pub use cluster::{execute_placement, execute_placement_with,
                  ClusterExecution, ExecOptions, ExecReport, LinkUse,
                  TaskExec};
pub use engine::{Engine, Event};
pub use failure::{correlated_script, sort_script, staggered_script,
                  FailureOutcome, FailurePlan};
pub use pipeline_sim::{simulate_pipeline, PipelineSimResult};
pub use trace::{Trace, TraceEvent};
