//! Discrete-event simulation of a ring all-reduce over WAN links —
//! validates the closed-form model in `parallel::cost::ring_allreduce_ms`
//! (Systems A and C are built on it) and exposes the per-step traffic
//! pattern for the ablation bench.
//!
//! Since the whole-placement executor landed ([`super::cluster`]) this
//! file is a thin lowering: the ring schedule — 2(n−1) barrier-stepped
//! rounds in which node `i` forwards a chunk to node `(i+1) mod n`, each
//! step paced by its slowest link — lives in
//! [`cluster::RingProfile`](super::cluster), shared with the
//! `Replicated`/`TensorSharded` placement lowerings. Here the collective
//! runs *alone on dedicated links* (the contention-free validation case),
//! and every per-link chunk completion is recorded in the
//! [`Trace`](super::trace::Trace) as a
//! [`TraceKind::RingStep`](super::trace::TraceKind) so traffic per ring
//! link is inspectable.

use super::cluster::run_ring_dedicated;
use super::trace::Trace;
use crate::cluster::Fleet;

/// Result of one simulated all-reduce.
#[derive(Clone, Debug)]
pub struct AllReduceSimResult {
    pub makespan_ms: f64,
    /// Per-step durations (length 2(n−1)).
    pub step_ms: Vec<f64>,
    /// Busy time per ring link.
    pub link_busy_ms: Vec<f64>,
    /// Per-link completions as `TraceKind::RingStep` records (empty
    /// unless `with_trace`).
    pub trace: Trace,
    pub events_processed: u64,
}

/// Simulate a ring all-reduce of `bytes` over `nodes` (machine ids, ring
/// order as given), alone on dedicated links. With `with_trace`, the
/// completed link of every chunk transfer is emitted into the trace.
/// Returns `None` if any ring edge is unreachable.
pub fn simulate_ring_allreduce(fleet: &Fleet, nodes: &[usize], bytes: f64,
                               with_trace: bool)
    -> Option<AllReduceSimResult>
{
    let run = run_ring_dedicated(fleet, nodes, bytes, with_trace)?;
    Some(AllReduceSimResult {
        makespan_ms: run.makespan_ms,
        step_ms: run.step_ms,
        link_busy_ms: run.link_busy_ms,
        trace: run.trace,
        events_processed: run.events_processed,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallel::ring_allreduce_ms;
    use crate::sim::trace::TraceKind;

    #[test]
    fn matches_analytic_model_exactly() {
        // Barrier-synchronized steps paced by the slowest link ⇒ the DES
        // must equal the closed form 2(n−1)·max_link.
        let fleet = Fleet::paper_evaluation(0);
        for k in [2usize, 4, 8, 16] {
            let nodes: Vec<usize> = (0..k).collect();
            let bytes = 3.4e8; // BERT-large fp16 grads
            let sim =
                simulate_ring_allreduce(&fleet, &nodes, bytes, false)
                    .unwrap();
            let analytic = ring_allreduce_ms(&fleet, &nodes, bytes).unwrap();
            assert!((sim.makespan_ms - analytic).abs() / analytic < 1e-9,
                    "k={k}: sim {} vs analytic {}", sim.makespan_ms,
                    analytic);
        }
    }

    #[test]
    fn single_node_is_free() {
        let fleet = Fleet::paper_toy(0);
        let r = simulate_ring_allreduce(&fleet, &[3], 1e9, false).unwrap();
        assert_eq!(r.makespan_ms, 0.0);
        assert_eq!(r.events_processed, 0);
    }

    #[test]
    fn step_count_is_2n_minus_2() {
        let fleet = Fleet::paper_toy(0);
        let nodes = [0, 1, 2, 3, 4];
        let r = simulate_ring_allreduce(&fleet, &nodes, 1e7, false).unwrap();
        assert_eq!(r.step_ms.len(), 8);
        assert!(r.step_ms.iter().all(|&s| s > 0.0));
        // One barrier event per step.
        assert_eq!(r.events_processed, 8);
    }

    #[test]
    fn blocked_edge_returns_none() {
        let mut fleet = Fleet::paper_toy(0);
        let paris = fleet.add_machine(
            crate::cluster::Region::Paris,
            crate::cluster::GpuModel::V100,
            8,
        );
        assert!(
            simulate_ring_allreduce(&fleet, &[0, paris], 1e6, false)
                .is_none()
        );
    }

    #[test]
    fn every_link_busy_equal_times() {
        // Each link carries exactly 2(n−1) chunks.
        let fleet = Fleet::paper_toy(0);
        let nodes = [0, 1, 2];
        let r = simulate_ring_allreduce(&fleet, &nodes, 3e6, false).unwrap();
        assert_eq!(r.link_busy_ms.len(), 3);
        for (k, &busy) in r.link_busy_ms.iter().enumerate() {
            assert!(busy > 0.0, "link {k} never used");
        }
    }

    #[test]
    fn trace_emits_the_completed_link_of_every_chunk() {
        let fleet = Fleet::paper_toy(0);
        let nodes = [0, 1, 2, 3];
        let r = simulate_ring_allreduce(&fleet, &nodes, 3e6, true).unwrap();
        // 2(n−1) steps × n links, each completion carrying its link id.
        assert_eq!(r.trace.len(), 6 * 4);
        for link in 0..4 {
            let recorded = r.trace.ring_link_busy_ms(link);
            assert!((recorded - r.link_busy_ms[link]).abs() < 1e-9,
                    "link {link}: trace {recorded} vs busy {}",
                    r.link_busy_ms[link]);
        }
        // Steps appear in order and cover the whole schedule.
        let steps: Vec<usize> = r
            .trace
            .events
            .iter()
            .filter_map(|e| match e.kind {
                TraceKind::RingStep { step, .. } => Some(step),
                _ => None,
            })
            .collect();
        assert_eq!(steps.first(), Some(&0));
        assert_eq!(steps.last(), Some(&5));
        // Untraced runs record nothing.
        let quiet =
            simulate_ring_allreduce(&fleet, &nodes, 3e6, false).unwrap();
        assert!(quiet.trace.is_empty());
    }
}
