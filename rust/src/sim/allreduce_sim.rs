//! Discrete-event simulation of a ring all-reduce over WAN links —
//! validates the closed-form model in `parallel::cost::ring_allreduce_ms`
//! (Systems A and C are built on it) and exposes the per-step traffic
//! pattern for the ablation bench.
//!
//! Schedule: 2(n−1) steps; in step `s` every node `i` sends chunk
//! `(i − s) mod n` to node `(i+1) mod n`. Steps are barrier-synchronized
//! (as in NCCL's ring): the step completes when the slowest link does —
//! which is precisely why a topology-oblivious ring across regions is
//! paced by its worst edge.

use super::engine::{Engine, Resource};
use crate::cluster::Fleet;
use crate::parallel::cost::p2p_ms;

/// Result of one simulated all-reduce.
#[derive(Clone, Debug)]
pub struct AllReduceSimResult {
    pub makespan_ms: f64,
    /// Per-step durations (length 2(n−1)).
    pub step_ms: Vec<f64>,
    /// Busy time per ring link.
    pub link_busy_ms: Vec<f64>,
    pub events_processed: u64,
}

#[derive(Clone, Copy, Debug)]
struct TransferDone {
    step: usize,
    /// Which ring link completed (kept for trace/debug output).
    #[allow(dead_code)]
    link: usize,
}

/// Simulate a ring all-reduce of `bytes` over `nodes` (machine ids, ring
/// order as given). Returns `None` if any ring edge is unreachable.
pub fn simulate_ring_allreduce(fleet: &Fleet, nodes: &[usize], bytes: f64)
    -> Option<AllReduceSimResult>
{
    let n = nodes.len();
    if n <= 1 {
        return Some(AllReduceSimResult {
            makespan_ms: 0.0,
            step_ms: Vec::new(),
            link_busy_ms: Vec::new(),
            events_processed: 0,
        });
    }
    let chunk = bytes / n as f64;
    // Per-link transfer time for one chunk.
    let mut link_ms = Vec::with_capacity(n);
    for k in 0..n {
        let a = nodes[k];
        let b = nodes[(k + 1) % n];
        link_ms.push(p2p_ms(fleet, a, b, chunk)?);
    }

    let total_steps = 2 * (n - 1);
    let mut engine: Engine<TransferDone> = Engine::new();
    let mut links = vec![Resource::default(); n];
    let mut step_ms = vec![0.0f64; total_steps];
    let mut pending = n; // transfers outstanding in the current step
    let mut step = 0usize;
    let mut step_started = 0.0f64;

    // Kick off step 0 on all links.
    for (k, &ms) in link_ms.iter().enumerate() {
        let done = links[k].occupy(0.0, ms);
        engine.schedule(done, TransferDone { step: 0, link: k });
    }

    let mut makespan = 0.0;
    while let Some(ev) = engine.next() {
        debug_assert_eq!(ev.payload.step, step);
        pending -= 1;
        if pending == 0 {
            // Barrier: step complete.
            step_ms[step] = engine.now_ms() - step_started;
            makespan = engine.now_ms();
            step += 1;
            if step == total_steps {
                break;
            }
            step_started = engine.now_ms();
            pending = n;
            for (k, &ms) in link_ms.iter().enumerate() {
                let done = links[k].occupy(engine.now_ms(), ms);
                engine.schedule(done, TransferDone { step, link: k });
            }
        }
    }

    Some(AllReduceSimResult {
        makespan_ms: makespan,
        step_ms,
        link_busy_ms: links.iter().map(|l| l.busy_ms()).collect(),
        events_processed: engine.events_processed,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallel::ring_allreduce_ms;

    #[test]
    fn matches_analytic_model_exactly() {
        // Barrier-synchronized steps paced by the slowest link ⇒ the DES
        // must equal the closed form 2(n−1)·max_link.
        let fleet = Fleet::paper_evaluation(0);
        for k in [2usize, 4, 8, 16] {
            let nodes: Vec<usize> = (0..k).collect();
            let bytes = 3.4e8; // BERT-large fp16 grads
            let sim = simulate_ring_allreduce(&fleet, &nodes, bytes).unwrap();
            let analytic = ring_allreduce_ms(&fleet, &nodes, bytes).unwrap();
            assert!((sim.makespan_ms - analytic).abs() / analytic < 1e-9,
                    "k={k}: sim {} vs analytic {}", sim.makespan_ms,
                    analytic);
        }
    }

    #[test]
    fn single_node_is_free() {
        let fleet = Fleet::paper_toy(0);
        let r = simulate_ring_allreduce(&fleet, &[3], 1e9).unwrap();
        assert_eq!(r.makespan_ms, 0.0);
        assert_eq!(r.events_processed, 0);
    }

    #[test]
    fn step_count_is_2n_minus_2() {
        let fleet = Fleet::paper_toy(0);
        let nodes = [0, 1, 2, 3, 4];
        let r = simulate_ring_allreduce(&fleet, &nodes, 1e7).unwrap();
        assert_eq!(r.step_ms.len(), 8);
        assert!(r.step_ms.iter().all(|&s| s > 0.0));
    }

    #[test]
    fn blocked_edge_returns_none() {
        let mut fleet = Fleet::paper_toy(0);
        let paris = fleet.add_machine(
            crate::cluster::Region::Paris,
            crate::cluster::GpuModel::V100,
            8,
        );
        assert!(simulate_ring_allreduce(&fleet, &[0, paris], 1e6).is_none());
    }

    #[test]
    fn every_link_busy_equal_times() {
        // Each link carries exactly 2(n−1) chunks.
        let fleet = Fleet::paper_toy(0);
        let nodes = [0, 1, 2];
        let r = simulate_ring_allreduce(&fleet, &nodes, 3e6).unwrap();
        for (k, &busy) in r.link_busy_ms.iter().enumerate() {
            assert!(busy > 0.0, "link {k} never used");
        }
        assert_eq!(r.events_processed as usize, 3 * 4);
    }
}
