//! Generic discrete-event engine: a time-ordered event queue with stable
//! FIFO tie-breaking and resource-availability helpers.
//!
//! Heap ordering runs on **fixed-point `u64` keys**, not on the `f64`
//! clock: simulation times are finite and non-negative, and for such
//! values the IEEE-754 bit pattern is strictly monotone in the value —
//! `to_bits` is a lossless order-isomorphic reinterpretation. Every
//! sift in the heap hot loop is therefore two integer compares (key,
//! then sequence number) instead of a `partial_cmp` + NaN-branch on
//! floats; event order — and with it every artifact byte — is
//! unchanged.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Heap key of an event time: the bit pattern of the (canonicalized)
/// non-negative `f64`, strictly monotone in the time. `-0.0` is folded
/// to `+0.0` so the two zeros cannot order differently than they
/// compare. NaN/negative times are rejected **here, at the scheduling
/// boundary, in every build** — one predictable branch per `schedule`
/// call replaces the old per-comparison `partial_cmp` NaN branch in
/// the heap sift (which is O(log n) comparisons per event), and a NaN
/// produced by a degenerate cost formula still fails loudly instead of
/// silently sorting last and poisoning the clock.
#[inline]
fn time_key(at_ms: f64) -> u64 {
    assert!(at_ms >= 0.0, "invalid event time {at_ms}"); // rejects NaN too
    (at_ms + 0.0).to_bits()
}

/// A scheduled event carrying a caller-defined payload.
#[derive(Clone, Debug)]
pub struct Event<P> {
    pub time_ms: f64,
    /// Fixed-point ordering key: `time_key(time_ms)`.
    key: u64,
    /// Monotone sequence number: equal-time events fire in insertion order.
    seq: u64,
    pub payload: P,
}

impl<P> PartialEq for Event<P> {
    fn eq(&self, other: &Self) -> bool {
        self.key == other.key && self.seq == other.seq
    }
}
impl<P> Eq for Event<P> {}

impl<P> Ord for Event<P> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap via reversed comparison — pure integer compares.
        other
            .key
            .cmp(&self.key)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}
impl<P> PartialOrd for Event<P> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// The engine: event queue + simulation clock.
pub struct Engine<P> {
    heap: BinaryHeap<Event<P>>,
    now_ms: f64,
    next_seq: u64,
    pub events_processed: u64,
}

impl<P> Engine<P> {
    pub fn new() -> Engine<P> {
        Engine { heap: BinaryHeap::new(), now_ms: 0.0, next_seq: 0,
                 events_processed: 0 }
    }

    /// An engine recycling `spare` as its queue storage — cleared, the
    /// capacity kept. Pair with [`Engine::into_spare`] to amortize the
    /// event-vector allocation across many short simulations.
    pub fn with_spare(mut spare: Vec<Event<P>>) -> Engine<P> {
        spare.clear();
        Engine { heap: BinaryHeap::from(spare), now_ms: 0.0, next_seq: 0,
                 events_processed: 0 }
    }

    /// Tear down, handing back the queue storage for reuse.
    pub fn into_spare(self) -> Vec<Event<P>> {
        let mut spare = self.heap.into_vec();
        spare.clear();
        spare
    }

    pub fn now_ms(&self) -> f64 {
        self.now_ms
    }

    /// Schedule `payload` at absolute time `at_ms` (≥ current clock).
    pub fn schedule(&mut self, at_ms: f64, payload: P) {
        debug_assert!(
            at_ms >= self.now_ms,
            "scheduling into the past: {} < {}",
            at_ms,
            self.now_ms
        );
        self.heap.push(Event { time_ms: at_ms, key: time_key(at_ms),
                               seq: self.next_seq, payload });
        self.next_seq += 1;
    }

    /// Schedule `payload` after a delay from now.
    pub fn schedule_in(&mut self, delay_ms: f64, payload: P) {
        self.schedule(self.now_ms + delay_ms, payload);
    }

    /// Pop the next event, advancing the clock.
    pub fn next(&mut self) -> Option<Event<P>> {
        let ev = self.heap.pop()?;
        self.now_ms = ev.time_ms;
        self.events_processed += 1;
        Some(ev)
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

impl<P> Default for Engine<P> {
    fn default() -> Self {
        Engine::new()
    }
}

/// A serially shared resource (a machine, a WAN link): tracks when it next
/// becomes free and serializes work placed on it.
#[derive(Clone, Copy, Debug, Default)]
pub struct Resource {
    free_at_ms: f64,
    busy_ms: f64,
}

impl Resource {
    /// Occupy the resource for `duration_ms` starting no earlier than
    /// `earliest_ms`; returns the completion time.
    pub fn occupy(&mut self, earliest_ms: f64, duration_ms: f64) -> f64 {
        let start = self.free_at_ms.max(earliest_ms);
        self.free_at_ms = start + duration_ms;
        self.busy_ms += duration_ms;
        self.free_at_ms
    }

    pub fn free_at(&self) -> f64 {
        self.free_at_ms
    }

    /// Total busy time (for utilization reports).
    pub fn busy_ms(&self) -> f64 {
        self.busy_ms
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_fire_in_time_order() {
        let mut e: Engine<&str> = Engine::new();
        e.schedule(5.0, "c");
        e.schedule(1.0, "a");
        e.schedule(3.0, "b");
        let order: Vec<&str> =
            std::iter::from_fn(|| e.next().map(|ev| ev.payload)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
        assert_eq!(e.now_ms(), 5.0);
        assert_eq!(e.events_processed, 3);
    }

    #[test]
    fn equal_times_fire_fifo() {
        let mut e: Engine<u32> = Engine::new();
        for i in 0..10 {
            e.schedule(2.0, i);
        }
        let order: Vec<u32> =
            std::iter::from_fn(|| e.next().map(|ev| ev.payload)).collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn schedule_in_is_relative() {
        let mut e: Engine<&str> = Engine::new();
        e.schedule(10.0, "first");
        e.next();
        e.schedule_in(5.0, "second");
        let ev = e.next().unwrap();
        assert_eq!(ev.time_ms, 15.0);
    }

    #[test]
    fn fixed_point_keys_preserve_float_ordering() {
        // to_bits is monotone for non-negative floats, zeros collapse.
        let times = [0.0, -0.0, 1e-12, 0.5, 1.0, 1.0 + f64::EPSILON,
                     1e3, 1e9, f64::MAX];
        for w in times.windows(2) {
            assert!(super::time_key(w[0]) <= super::time_key(w[1]),
                    "{} vs {}", w[0], w[1]);
        }
        assert_eq!(super::time_key(-0.0), super::time_key(0.0));
        assert!(super::time_key(0.0) < super::time_key(f64::MIN_POSITIVE));
    }

    #[test]
    fn spare_recycling_keeps_capacity_and_behavior() {
        let mut e: Engine<u32> = Engine::new();
        for i in 0..10 {
            e.schedule(i as f64, i);
        }
        e.next();
        let spare = e.into_spare();
        assert!(spare.is_empty());
        assert!(spare.capacity() >= 9);
        let mut e: Engine<u32> = Engine::with_spare(spare);
        assert_eq!(e.now_ms(), 0.0);
        e.schedule(2.0, 7);
        e.schedule(1.0, 3);
        assert_eq!(e.next().unwrap().payload, 3);
        assert_eq!(e.next().unwrap().payload, 7);
    }

    #[test]
    fn resource_serializes_work() {
        let mut r = Resource::default();
        let t1 = r.occupy(0.0, 10.0);
        assert_eq!(t1, 10.0);
        // Requested at t=5 but resource busy until 10.
        let t2 = r.occupy(5.0, 10.0);
        assert_eq!(t2, 20.0);
        // Requested after the resource is free: starts immediately.
        let t3 = r.occupy(30.0, 5.0);
        assert_eq!(t3, 35.0);
        assert_eq!(r.busy_ms(), 25.0);
    }
}
