//! Failure injection for disaster-recovery experiments (paper §1: "How can
//! we address the issue of disaster recovery in training, such as handling
//! scenarios where a machine fails during the process?").

/// A planned machine failure during a simulated run.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FailurePlan {
    /// Simulation time at which the machine dies.
    pub at_ms: f64,
    /// Machine id (must be one of the participating machines to have any
    /// effect).
    pub machine: usize,
}

/// What the simulator observed about an injected failure.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FailureOutcome {
    pub at_ms: f64,
    pub machine: usize,
    /// Microbatches fully processed (fwd+bwd) before the failure — the
    /// work that survives in optimizer state and does not need redoing.
    pub completed_microbatches: usize,
}

/// Canonical order for a multi-failure script: ascending time, machine
/// id breaking ties. Generators and replayers both sort through here so
/// a script compares equal regardless of construction order.
pub fn sort_script(script: &mut [FailurePlan]) {
    script.sort_by(|a, b| {
        a.at_ms
            .total_cmp(&b.at_ms)
            .then(a.machine.cmp(&b.machine))
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sort_script_orders_by_time_then_machine() {
        let mut script = vec![
            FailurePlan { at_ms: 50.0, machine: 3 },
            FailurePlan { at_ms: 10.0, machine: 7 },
            FailurePlan { at_ms: 50.0, machine: 1 },
        ];
        sort_script(&mut script);
        let order: Vec<usize> = script.iter().map(|f| f.machine).collect();
        assert_eq!(order, vec![7, 1, 3]);
    }

    #[test]
    fn plan_is_plain_data() {
        let p = FailurePlan { at_ms: 100.0, machine: 3 };
        assert_eq!(p, p.clone());
        let o = FailureOutcome { at_ms: 100.0, machine: 3,
                                 completed_microbatches: 2 };
        assert_eq!(o.machine, 3);
    }
}
