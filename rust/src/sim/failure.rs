//! Failure injection for disaster-recovery experiments (paper §1: "How can
//! we address the issue of disaster recovery in training, such as handling
//! scenarios where a machine fails during the process?").

/// A planned machine failure during a simulated run.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FailurePlan {
    /// Simulation time at which the machine dies.
    pub at_ms: f64,
    /// Machine id (must be one of the participating machines to have any
    /// effect).
    pub machine: usize,
}

/// What the simulator observed about an injected failure.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FailureOutcome {
    pub at_ms: f64,
    pub machine: usize,
    /// Microbatches fully processed (fwd+bwd) before the failure — the
    /// work that survives in optimizer state and does not need redoing.
    pub completed_microbatches: usize,
}

/// Canonical order for a multi-failure script: ascending time, machine
/// id breaking ties. Generators and replayers both sort through here so
/// a script compares equal regardless of construction order.
pub fn sort_script(script: &mut [FailurePlan]) {
    script.sort_by(|a, b| {
        a.at_ms
            .total_cmp(&b.at_ms)
            .then(a.machine.cmp(&b.machine))
    });
}

/// A correlated outage: every machine dies at the same instant (one
/// regional blast radius), canonically ordered.
pub fn correlated_script(at_ms: f64, machines: &[usize])
    -> Vec<FailurePlan>
{
    let mut script: Vec<FailurePlan> = machines
        .iter()
        .map(|&machine| FailurePlan { at_ms, machine })
        .collect();
    sort_script(&mut script);
    script
}

/// A staggered wave: machine k dies at `start_ms + k * gap_ms` in the
/// order given (spot-revocation notices arriving one by one).
pub fn staggered_script(machines: &[usize], start_ms: f64, gap_ms: f64)
    -> Vec<FailurePlan>
{
    machines
        .iter()
        .enumerate()
        .map(|(k, &machine)| FailurePlan {
            at_ms: start_ms + k as f64 * gap_ms,
            machine,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sort_script_orders_by_time_then_machine() {
        let mut script = vec![
            FailurePlan { at_ms: 50.0, machine: 3 },
            FailurePlan { at_ms: 10.0, machine: 7 },
            FailurePlan { at_ms: 50.0, machine: 1 },
        ];
        sort_script(&mut script);
        let order: Vec<usize> = script.iter().map(|f| f.machine).collect();
        assert_eq!(order, vec![7, 1, 3]);
    }

    #[test]
    fn correlated_script_shares_one_instant_and_sorts_by_id() {
        let script = correlated_script(120.0, &[9, 2, 5]);
        assert!(script.iter().all(|f| f.at_ms == 120.0));
        let ids: Vec<usize> = script.iter().map(|f| f.machine).collect();
        assert_eq!(ids, vec![2, 5, 9]);
    }

    #[test]
    fn staggered_script_spaces_failures_by_gap() {
        let script = staggered_script(&[4, 8, 1], 100.0, 40.0);
        assert_eq!(script.len(), 3);
        assert_eq!(script[0],
                   FailurePlan { at_ms: 100.0, machine: 4 });
        assert_eq!(script[1],
                   FailurePlan { at_ms: 140.0, machine: 8 });
        assert_eq!(script[2],
                   FailurePlan { at_ms: 180.0, machine: 1 });
        // Already canonical when the wave is ascending in time.
        let mut sorted = script.clone();
        sort_script(&mut sorted);
        assert_eq!(sorted, script);
    }

    #[test]
    fn plan_is_plain_data() {
        let p = FailurePlan { at_ms: 100.0, machine: 3 };
        assert_eq!(p, p.clone());
        let o = FailureOutcome { at_ms: 100.0, machine: 3,
                                 completed_microbatches: 2 };
        assert_eq!(o.machine, 3);
    }
}
