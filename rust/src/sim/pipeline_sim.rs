//! Discrete-event execution of a GPipe schedule over WAN links.
//!
//! Differences from the analytic model in `parallel::pipeline`:
//! transfers genuinely serialize on links, stages genuinely idle during
//! the flush, and failures can interrupt mid-iteration. The ablation bench
//! (`hulk bench ablation`) compares the two.

use super::engine::{Engine, Resource};
use super::failure::{FailureOutcome, FailurePlan};
use super::trace::{Trace, TraceKind};
use crate::cluster::Fleet;
use crate::models::ModelSpec;
use crate::parallel::cost::p2p_ms;
use crate::parallel::PipelinePlan;

/// Simulation outcome for one training iteration.
#[derive(Clone, Debug)]
pub struct PipelineSimResult {
    /// Wall-clock of the iteration (∞ if it failed before completing).
    pub makespan_ms: f64,
    /// Total busy time across stages (compute).
    pub comp_busy_ms: f64,
    /// Total busy time across boundary links (communication).
    pub comm_busy_ms: f64,
    /// Mean stage utilization (busy / makespan).
    pub mean_utilization: f64,
    /// Set when a failure interrupted the run.
    pub failure: Option<FailureOutcome>,
    pub trace: Trace,
    pub events_processed: u64,
}

#[derive(Clone, Copy, Debug)]
enum Ev {
    FwdReady { stage: usize, mb: usize },
    BwdReady { stage: usize, mb: usize },
    Fail { machine: usize },
}

/// Simulate one GPipe iteration of `plan` for `model` on `fleet`.
///
/// Panics if the plan's boundaries are unreachable (callers must check
/// feasibility via `parallel::pipeline_cost` first — the simulator is for
/// feasible plans).
pub fn simulate_pipeline(fleet: &Fleet, plan: &PipelinePlan,
                         model: &ModelSpec, with_trace: bool,
                         failure: Option<FailurePlan>) -> PipelineSimResult
{
    let s = plan.n_stages();
    let k = plan.microbatches;
    let micro_batch =
        ((model.batch as f64 / k as f64).ceil() as usize).max(1);
    let micro_tokens = (micro_batch * model.seq_len) as f64;
    let act_bytes = model.activation_bytes(micro_batch);

    // Per-stage fwd/bwd compute times (6×params split 2 fwd : 4 bwd).
    let mut fwd_ms = Vec::with_capacity(s);
    let mut bwd_ms = Vec::with_capacity(s);
    for (i, &m) in plan.stages.iter().enumerate() {
        let frac = plan.layers[i] as f64 / model.layers as f64;
        let flops = crate::models::FLOPS_PER_TOKEN_FACTOR
            * model.params
            * frac
            * micro_tokens;
        let total = flops / (fleet.machines[m].total_tflops() * 1e12) * 1e3;
        fwd_ms.push(total / 3.0);
        bwd_ms.push(total * 2.0 / 3.0);
    }
    // Per-boundary transfer time for one microbatch activation.
    let link_ms: Vec<f64> = (0..s.saturating_sub(1))
        .map(|i| {
            p2p_ms(fleet, plan.stages[i], plan.stages[i + 1], act_bytes)
                .expect("simulate_pipeline: unreachable boundary")
        })
        .collect();

    let mut engine: Engine<Ev> = Engine::new();
    let mut stage_res = vec![Resource::default(); s];
    let mut link_res = vec![Resource::default(); s.saturating_sub(1)];
    let mut trace = if with_trace { Trace::enabled() } else { Trace::disabled() };

    if let Some(f) = failure {
        engine.schedule(f.at_ms, Ev::Fail { machine: f.machine });
    }
    for mb in 0..k {
        engine.schedule(0.0, Ev::FwdReady { stage: 0, mb });
    }

    let mut fwd_done_at_last = 0usize;
    let mut bwd_done_at_first = 0usize;
    let mut bwd_completed = vec![false; k];
    let mut makespan = f64::INFINITY;
    let mut failed: Option<FailureOutcome> = None;

    while let Some(ev) = engine.next() {
        let now = ev.time_ms;
        match ev.payload {
            Ev::Fail { machine } => {
                if plan.stages.contains(&machine) {
                    failed = Some(FailureOutcome {
                        at_ms: now,
                        machine,
                        completed_microbatches: bwd_completed
                            .iter()
                            .filter(|&&d| d)
                            .count(),
                    });
                    trace.record(now, TraceKind::Failure { machine });
                    break;
                }
            }
            Ev::FwdReady { stage, mb } => {
                let done = stage_res[stage].occupy(now, fwd_ms[stage]);
                trace.record(done, TraceKind::Compute {
                    stage, mb, backward: false, dur_ms: fwd_ms[stage] });
                if stage + 1 < s {
                    let arr = link_res[stage].occupy(done, link_ms[stage]);
                    trace.record(arr, TraceKind::Transfer {
                        boundary: stage, mb, backward: false,
                        dur_ms: link_ms[stage] });
                    engine.schedule(arr, Ev::FwdReady { stage: stage + 1, mb });
                } else {
                    fwd_done_at_last += 1;
                    if fwd_done_at_last == k {
                        // GPipe flush: backward starts after the full
                        // forward wave, last microbatch first.
                        for b in (0..k).rev() {
                            engine.schedule(done, Ev::BwdReady {
                                stage: s - 1, mb: b });
                        }
                    }
                }
            }
            Ev::BwdReady { stage, mb } => {
                let done = stage_res[stage].occupy(now, bwd_ms[stage]);
                trace.record(done, TraceKind::Compute {
                    stage, mb, backward: true, dur_ms: bwd_ms[stage] });
                if stage > 0 {
                    let arr =
                        link_res[stage - 1].occupy(done, link_ms[stage - 1]);
                    trace.record(arr, TraceKind::Transfer {
                        boundary: stage - 1, mb, backward: true,
                        dur_ms: link_ms[stage - 1] });
                    engine.schedule(arr, Ev::BwdReady { stage: stage - 1, mb });
                } else {
                    bwd_completed[mb] = true;
                    bwd_done_at_first += 1;
                    if bwd_done_at_first == k {
                        makespan = done;
                        break;
                    }
                }
            }
        }
    }

    let comp_busy_ms: f64 = stage_res.iter().map(|r| r.busy_ms()).sum();
    let comm_busy_ms: f64 = link_res.iter().map(|r| r.busy_ms()).sum();
    let mean_utilization = if makespan.is_finite() && s > 0 {
        stage_res
            .iter()
            .map(|r| r.busy_ms() / makespan)
            .sum::<f64>()
            / s as f64
    } else {
        0.0
    };
    PipelineSimResult {
        makespan_ms: makespan,
        comp_busy_ms,
        comm_busy_ms,
        mean_utilization,
        failure: failed,
        trace,
        events_processed: engine.events_processed,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallel::pipeline_cost;

    fn setup() -> (Fleet, PipelinePlan, ModelSpec) {
        let fleet = Fleet::paper_toy(0);
        let model = ModelSpec::gpt2_xl();
        let plan = PipelinePlan::proportional(
            &fleet, vec![0, 1, 2, 3], &model);
        (fleet, plan, model)
    }

    #[test]
    fn completes_with_finite_makespan() {
        let (fleet, plan, model) = setup();
        let r = simulate_pipeline(&fleet, &plan, &model, false, None);
        assert!(r.makespan_ms.is_finite());
        assert!(r.failure.is_none());
        assert!(r.comp_busy_ms > 0.0 && r.comm_busy_ms > 0.0);
        assert!(r.events_processed > 0);
    }

    #[test]
    fn makespan_bounded_below_by_critical_path() {
        let (fleet, plan, model) = setup();
        let r = simulate_pipeline(&fleet, &plan, &model, false, None);
        // Makespan ≥ busiest stage's total work, and ≥ one full wave.
        let s = plan.n_stages();
        let per_stage = r.comp_busy_ms / s as f64; // equalized-ish split
        assert!(r.makespan_ms >= per_stage * 0.9);
    }

    #[test]
    fn single_stage_pipeline_has_no_comm() {
        let fleet = Fleet::paper_toy(0);
        let model = ModelSpec::bert_large();
        let plan = PipelinePlan::proportional(&fleet, vec![2], &model);
        let r = simulate_pipeline(&fleet, &plan, &model, false, None);
        assert_eq!(r.comm_busy_ms, 0.0);
        assert!(r.makespan_ms.is_finite());
    }

    #[test]
    fn utilization_in_unit_range() {
        let (fleet, plan, model) = setup();
        let r = simulate_pipeline(&fleet, &plan, &model, true, None);
        assert!(r.mean_utilization > 0.0 && r.mean_utilization <= 1.0);
        assert!(!r.trace.is_empty());
    }

    #[test]
    fn agrees_with_analytic_model_on_order_of_magnitude() {
        let (fleet, plan, model) = setup();
        let sim = simulate_pipeline(&fleet, &plan, &model, false, None);
        let analytic = pipeline_cost(&fleet, &plan, &model);
        let ratio = sim.makespan_ms / analytic.total_ms();
        assert!((0.2..5.0).contains(&ratio),
                "sim {} vs analytic {}", sim.makespan_ms,
                analytic.total_ms());
    }

    #[test]
    fn failure_interrupts_run() {
        let (fleet, plan, model) = setup();
        let healthy = simulate_pipeline(&fleet, &plan, &model, false, None);
        let fail_at = healthy.makespan_ms * 0.3;
        let r = simulate_pipeline(&fleet, &plan, &model, true,
            Some(FailurePlan { at_ms: fail_at, machine: plan.stages[1] }));
        let outcome = r.failure.expect("failure must be observed");
        assert_eq!(outcome.machine, plan.stages[1]);
        assert!(r.makespan_ms.is_infinite());
        assert!((outcome.at_ms - fail_at).abs() < 1e-9);
    }

    #[test]
    fn failure_of_nonparticipant_is_ignored() {
        let (fleet, plan, model) = setup();
        // Machine 7 is not in stages [0,1,2,3].
        let r = simulate_pipeline(&fleet, &plan, &model, false,
            Some(FailurePlan { at_ms: 1.0, machine: 7 }));
        assert!(r.failure.is_none());
        assert!(r.makespan_ms.is_finite());
    }

    #[test]
    fn more_microbatches_amortize_bubble() {
        let (fleet, mut plan, model) = setup();
        plan.microbatches = 2;
        let few = simulate_pipeline(&fleet, &plan, &model, false, None);
        plan.microbatches = 16;
        let many = simulate_pipeline(&fleet, &plan, &model, false, None);
        // Throughput per microbatch must improve with more microbatches.
        assert!(many.makespan_ms / 16.0 < few.makespan_ms / 2.0);
    }
}
