//! Discrete-event execution of a GPipe schedule over WAN links.
//!
//! Since the whole-placement executor landed ([`super::cluster`]) this
//! file is a thin lowering: it wraps the single pipeline as a one-task
//! [`Placement`](crate::planner::Placement) and executes it on the unified
//! engine (machines and WAN links as shared [`Resource`]s, failure
//! injection, traces), then projects the per-task outcome back into the
//! historical [`PipelineSimResult`] shape. Differences from the analytic
//! model in `parallel::pipeline` remain the point: transfers genuinely
//! serialize on links, stages genuinely idle during the flush, and
//! failures can interrupt mid-iteration. The ablation bench
//! (`hulk bench ablation`) compares the two.
//!
//! [`Resource`]: super::engine::Resource

use super::cluster::{execute_placement_with, ExecOptions};
use super::failure::{FailureOutcome, FailurePlan};
use super::trace::Trace;
use crate::cluster::Fleet;
use crate::models::ModelSpec;
use crate::parallel::{pipeline_cost, PipelinePlan};
use crate::planner::{Placement, TaskPlacement};

/// Simulation outcome for one training iteration.
#[derive(Clone, Debug)]
pub struct PipelineSimResult {
    /// Wall-clock of the iteration (∞ if it failed before completing).
    pub makespan_ms: f64,
    /// Total busy time across stages (compute).
    pub comp_busy_ms: f64,
    /// Total busy time across boundary links (communication).
    pub comm_busy_ms: f64,
    /// Mean stage utilization (busy / makespan).
    pub mean_utilization: f64,
    /// Set when a failure interrupted the run.
    pub failure: Option<FailureOutcome>,
    pub trace: Trace,
    pub events_processed: u64,
}

/// Simulate one GPipe iteration of `plan` for `model` on `fleet`.
///
/// Panics if the plan is not executable (callers must check feasibility
/// via `parallel::pipeline_cost` first — the simulator is for feasible
/// plans).
pub fn simulate_pipeline(fleet: &Fleet, plan: &PipelinePlan,
                         model: &ModelSpec, with_trace: bool,
                         failure: Option<FailurePlan>) -> PipelineSimResult
{
    assert!(
        pipeline_cost(fleet, plan, model).is_feasible(),
        "simulate_pipeline: infeasible plan (unreachable boundary or \
         oversized stage shard) — check pipeline_cost first"
    );
    let placement = Placement {
        per_task: vec![TaskPlacement::PipelineStages {
            stages: plan.stages.clone(),
            layers: plan.layers.clone(),
            microbatches: plan.microbatches,
        }],
    };
    let run = execute_placement_with(
        fleet,
        std::slice::from_ref(model),
        &placement,
        // Dedicated links: this is the single-schedule validation path,
        // numerically matched to the historical per-boundary simulator.
        ExecOptions { with_trace, failure, dedicated_links: true },
    );
    let task = &run.tasks[0];
    let s = plan.n_stages();
    let makespan_ms = task.finish_ms;
    let mean_utilization = if makespan_ms.is_finite() && s > 0 {
        task.comp_busy_ms / makespan_ms / s as f64
    } else {
        0.0
    };
    PipelineSimResult {
        makespan_ms,
        comp_busy_ms: task.comp_busy_ms,
        comm_busy_ms: task.comm_busy_ms,
        mean_utilization,
        failure: run.failure,
        trace: run.trace,
        events_processed: run.report.events_processed,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (Fleet, PipelinePlan, ModelSpec) {
        let fleet = Fleet::paper_toy(0);
        let model = ModelSpec::gpt2_xl();
        let plan = PipelinePlan::proportional(
            &fleet, vec![0, 1, 2, 3], &model);
        (fleet, plan, model)
    }

    #[test]
    fn completes_with_finite_makespan() {
        let (fleet, plan, model) = setup();
        let r = simulate_pipeline(&fleet, &plan, &model, false, None);
        assert!(r.makespan_ms.is_finite());
        assert!(r.failure.is_none());
        assert!(r.comp_busy_ms > 0.0 && r.comm_busy_ms > 0.0);
        assert!(r.events_processed > 0);
    }

    #[test]
    fn makespan_bounded_below_by_critical_path() {
        let (fleet, plan, model) = setup();
        let r = simulate_pipeline(&fleet, &plan, &model, false, None);
        // Makespan ≥ busiest stage's total work, and ≥ one full wave.
        let s = plan.n_stages();
        let per_stage = r.comp_busy_ms / s as f64; // equalized-ish split
        assert!(r.makespan_ms >= per_stage * 0.9);
    }

    #[test]
    fn single_stage_pipeline_has_no_comm() {
        let fleet = Fleet::paper_toy(0);
        let model = ModelSpec::bert_large();
        let plan = PipelinePlan::proportional(&fleet, vec![2], &model);
        let r = simulate_pipeline(&fleet, &plan, &model, false, None);
        assert_eq!(r.comm_busy_ms, 0.0);
        assert!(r.makespan_ms.is_finite());
    }

    #[test]
    fn utilization_in_unit_range() {
        let (fleet, plan, model) = setup();
        let r = simulate_pipeline(&fleet, &plan, &model, true, None);
        assert!(r.mean_utilization > 0.0 && r.mean_utilization <= 1.0);
        assert!(!r.trace.is_empty());
    }

    #[test]
    fn agrees_with_analytic_model_on_order_of_magnitude() {
        let (fleet, plan, model) = setup();
        let sim = simulate_pipeline(&fleet, &plan, &model, false, None);
        let analytic = pipeline_cost(&fleet, &plan, &model);
        let ratio = sim.makespan_ms / analytic.total_ms();
        assert!((0.2..5.0).contains(&ratio),
                "sim {} vs analytic {}", sim.makespan_ms,
                analytic.total_ms());
    }

    #[test]
    fn failure_interrupts_run() {
        let (fleet, plan, model) = setup();
        let healthy = simulate_pipeline(&fleet, &plan, &model, false, None);
        let fail_at = healthy.makespan_ms * 0.3;
        let r = simulate_pipeline(&fleet, &plan, &model, true,
            Some(FailurePlan { at_ms: fail_at, machine: plan.stages[1] }));
        let outcome = r.failure.expect("failure must be observed");
        assert_eq!(outcome.machine, plan.stages[1]);
        assert!(r.makespan_ms.is_infinite());
        assert!((outcome.at_ms - fail_at).abs() < 1e-9);
    }

    #[test]
    fn failure_of_nonparticipant_is_ignored() {
        let (fleet, plan, model) = setup();
        // Machine 7 is not in stages [0,1,2,3].
        let r = simulate_pipeline(&fleet, &plan, &model, false,
            Some(FailurePlan { at_ms: 1.0, machine: 7 }));
        assert!(r.failure.is_none());
        assert!(r.makespan_ms.is_finite());
    }

    #[test]
    fn more_microbatches_amortize_bubble() {
        let (fleet, mut plan, model) = setup();
        plan.microbatches = 2;
        let few = simulate_pipeline(&fleet, &plan, &model, false, None);
        plan.microbatches = 16;
        let many = simulate_pipeline(&fleet, &plan, &model, false, None);
        // Throughput per microbatch must improve with more microbatches.
        assert!(many.makespan_ms / 16.0 < few.makespan_ms / 2.0);
    }
}
