//! Execution traces: what ran where and when, plus utilization summaries.

/// One trace record.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceEvent {
    pub time_ms: f64,
    pub kind: TraceKind,
}

#[derive(Clone, Debug, PartialEq)]
pub enum TraceKind {
    /// Stage `stage` finished computing microbatch `mb` (fwd or bwd).
    Compute { stage: usize, mb: usize, backward: bool, dur_ms: f64 },
    /// Transfer of microbatch `mb` over boundary `stage → stage+1` (fwd)
    /// or `stage+1 → stage` (bwd) completed.
    Transfer { boundary: usize, mb: usize, backward: bool, dur_ms: f64 },
    /// Ring link `link` finished its chunk transfer for step `step` of a
    /// ring all-reduce (the per-link completion record of
    /// `sim::allreduce_sim`).
    RingStep { link: usize, step: usize, dur_ms: f64 },
    /// Machine failed.
    Failure { machine: usize },
}

/// Append-only trace with summary queries.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub events: Vec<TraceEvent>,
    enabled: bool,
}

impl Trace {
    pub fn enabled() -> Trace {
        Trace { events: Vec::new(), enabled: true }
    }

    /// An enabled trace pre-sized for `capacity` records — callers that
    /// know the schedule shape (microbatches × stages × directions)
    /// avoid regrowing the buffer mid-simulation.
    pub fn enabled_with_capacity(capacity: usize) -> Trace {
        Trace { events: Vec::with_capacity(capacity), enabled: true }
    }

    /// A disabled trace records nothing (hot-path mode).
    pub fn disabled() -> Trace {
        Trace { events: Vec::new(), enabled: false }
    }

    pub fn record(&mut self, time_ms: f64, kind: TraceKind) {
        if self.enabled {
            self.events.push(TraceEvent { time_ms, kind });
        }
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Total compute time recorded for a stage.
    pub fn stage_busy_ms(&self, stage: usize) -> f64 {
        self.events
            .iter()
            .filter_map(|e| match e.kind {
                TraceKind::Compute { stage: s, dur_ms, .. } if s == stage => {
                    Some(dur_ms)
                }
                _ => None,
            })
            .sum()
    }

    /// Total transfer time recorded for a boundary.
    pub fn boundary_busy_ms(&self, boundary: usize) -> f64 {
        self.events
            .iter()
            .filter_map(|e| match e.kind {
                TraceKind::Transfer { boundary: b, dur_ms, .. }
                    if b == boundary =>
                {
                    Some(dur_ms)
                }
                _ => None,
            })
            .sum()
    }

    /// Total ring-transfer time recorded for ring link `link`.
    pub fn ring_link_busy_ms(&self, link: usize) -> f64 {
        self.events
            .iter()
            .filter_map(|e| match e.kind {
                TraceKind::RingStep { link: l, dur_ms, .. } if l == link => {
                    Some(dur_ms)
                }
                _ => None,
            })
            .sum()
    }

    /// Fraction of `makespan_ms` stage `stage` spent computing.
    pub fn stage_utilization(&self, stage: usize, makespan_ms: f64) -> f64 {
        if makespan_ms <= 0.0 {
            return 0.0;
        }
        self.stage_busy_ms(stage) / makespan_ms
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let mut t = Trace::enabled();
        t.record(1.0, TraceKind::Compute {
            stage: 0, mb: 0, backward: false, dur_ms: 5.0 });
        t.record(2.0, TraceKind::Compute {
            stage: 0, mb: 1, backward: true, dur_ms: 7.0 });
        t.record(3.0, TraceKind::Transfer {
            boundary: 0, mb: 0, backward: false, dur_ms: 2.0 });
        t.record(4.0, TraceKind::RingStep { link: 1, step: 0, dur_ms: 3.0 });
        t.record(7.0, TraceKind::RingStep { link: 1, step: 1, dur_ms: 3.0 });
        assert_eq!(t.len(), 5);
        assert_eq!(t.stage_busy_ms(0), 12.0);
        assert_eq!(t.stage_busy_ms(1), 0.0);
        assert_eq!(t.boundary_busy_ms(0), 2.0);
        assert_eq!(t.ring_link_busy_ms(1), 6.0);
        assert_eq!(t.ring_link_busy_ms(0), 0.0);
        assert!((t.stage_utilization(0, 24.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn disabled_trace_records_nothing() {
        let mut t = Trace::disabled();
        t.record(1.0, TraceKind::Failure { machine: 3 });
        assert!(t.is_empty());
    }

    #[test]
    fn utilization_handles_zero_makespan() {
        let t = Trace::enabled();
        assert_eq!(t.stage_utilization(0, 0.0), 0.0);
    }
}
