//! The unified whole-placement executor: lowers every
//! [`TaskPlacement`](crate::planner::TaskPlacement) variant of a
//! [`Placement`](crate::planner::Placement) into events on the shared
//! [`Engine`], with every **inter-region WAN link** and every **machine**
//! modelled as a serially shared [`Resource`]. Concurrent tasks therefore
//! contend for the same trans-continental links and the same compute — the
//! cross-task interference the per-task closed forms in
//! [`crate::parallel`] cannot see, and the effect that dominates when many
//! groups train at once over a regionally distributed fleet.
//!
//! This module is the execution backend behind
//! [`CostBackend::Simulated`](crate::planner::CostBackend); the historical
//! single-schedule simulators ([`super::allreduce_sim`],
//! [`super::pipeline_sim`]) are thin lowerings onto the machinery here.
//!
//! ## Lowering rules (one training iteration per task, all starting at t=0)
//!
//! - `Replicated {participants}` — every participant occupies its machine
//!   for the proportional-batch compute share (the analytic 5% straggler
//!   factor included), a barrier waits for the slowest, then a
//!   2(n−1)-step ring all-reduce of the fp16 gradients runs step by step.
//! - `TensorSharded {group}` — the perfectly split compute phase, then
//!   `layers × 4` ring all-reduces of the full-batch activation tensor,
//!   each lowered to its 2(n−1) barrier-stepped rounds.
//! - `PipelineStages` / `Grouped` — the GPipe schedule: K forward
//!   microbatches wave through the stages, the flush, then K backward
//!   microbatches; stage compute occupies the (shared) machine, boundary
//!   transfers occupy the shared WAN link of the region pair.
//!
//! ## Contention semantics
//!
//! - **Inter-region links** are one [`Resource`] per unordered region
//!   pair: transfers from *different tasks* (or different pipeline
//!   boundaries) crossing the same pair serialize in event order.
//! - **Within one collective step**, a task's parallel ring edges that
//!   map to the same region pair ride as a single bulk flow paced by the
//!   slowest edge (NCCL-style), so a lone task reproduces the closed form
//!   `2(n−1)·max_edge` exactly — the cross-validation contract with
//!   `parallel::cost::ring_allreduce_ms`.
//! - **Intra-region transfers** are pure delays on dedicated local
//!   fabric: per-boundary private serialization for pipelines (as in the
//!   original `pipeline_sim`), no shared metro bottleneck.
//! - **Machines** serialize compute across tasks, so placements that hand
//!   the whole fleet to every task (Systems A/B/C) genuinely queue.
//!
//! Everything is a pure function of its inputs — no wall clock, no global
//! state — so `--cost sim` artifacts are byte-identical across serial and
//! parallel scenario runs.

use crate::cluster::{Fleet, Region};
use crate::models::ModelSpec;
use crate::parallel::cost::p2p_ms;
use crate::parallel::IterCost;
use crate::planner::{Placement, TaskPlacement};

use super::engine::{Engine, Resource};
use super::failure::{FailureOutcome, FailurePlan};
use super::trace::{Trace, TraceKind};

/// Execution options (failure injection, tracing and dedicated links are
/// only meaningful for validation runs; the cost backend uses the
/// defaults).
#[derive(Clone, Copy, Debug, Default)]
pub struct ExecOptions {
    pub with_trace: bool,
    pub failure: Option<FailurePlan>,
    /// Route *every* pipeline boundary through a private per-boundary
    /// resource instead of the shared WAN pair — the contention-free
    /// validation mode [`super::simulate_pipeline`] runs in, which keeps
    /// it numerically identical to the historical per-boundary simulator
    /// even when one pipeline crosses the same region pair twice.
    pub dedicated_links: bool,
}

/// Traffic observed on one inter-region WAN link.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkUse {
    pub a: Region,
    pub b: Region,
    pub busy_ms: f64,
    /// `busy / makespan` (0 when the makespan is not finite-positive).
    pub utilization: f64,
}

/// Per-task outcome of a whole-placement execution.
#[derive(Clone, Debug)]
pub struct TaskExec {
    /// Simulated per-iteration cost: `total_ms()` is the task's simulated
    /// wall-clock; `comp_ms` is the pacing machine's busy time and
    /// `comm_ms` the residual (communication + contention + stragglers).
    /// Tasks the analytic models reject stay [`IterCost::infeasible`] —
    /// the two backends always agree on feasibility.
    pub cost: IterCost,
    /// Wall-clock finish (∞ for infeasible or interrupted tasks).
    pub finish_ms: f64,
    /// Total machine busy time attributed to this task.
    pub comp_busy_ms: f64,
    /// Total transfer time attributed to this task.
    pub comm_busy_ms: f64,
}

/// The contention digest reported alongside the per-task costs.
#[derive(Clone, Debug)]
pub struct ExecReport {
    /// Wall-clock until the last feasible task finishes (∞ if a failure
    /// halted the run; 0 when nothing was executable).
    pub makespan_ms: f64,
    /// How long the earliest-finishing task waits for the last one.
    pub straggler_wait_ms: f64,
    /// Every inter-region link that carried traffic, region-index order.
    pub links: Vec<LinkUse>,
    pub events_processed: u64,
}

impl LinkUse {
    /// Does this link connect `x` and `y` (either orientation)?
    pub fn connects(&self, x: Region, y: Region) -> bool {
        (self.a == x && self.b == y) || (self.a == y && self.b == x)
    }
}

impl ExecReport {
    /// The hottest link (by utilization), if any carried traffic.
    pub fn hottest_link(&self) -> Option<&LinkUse> {
        self.links.iter().max_by(|x, y| {
            x.utilization
                .total_cmp(&y.utilization)
                .then_with(|| y.a.index().cmp(&x.a.index()))
        })
    }
}

/// A complete whole-placement execution.
#[derive(Clone, Debug)]
pub struct ClusterExecution {
    /// One entry per workload task, placement order.
    pub tasks: Vec<TaskExec>,
    pub report: ExecReport,
    pub failure: Option<FailureOutcome>,
    pub trace: Trace,
}

impl ClusterExecution {
    /// The simulated per-task costs (the `Simulated` backend's columns).
    pub fn per_task_costs(&self) -> Vec<IterCost> {
        self.tasks.iter().map(|t| t.cost).collect()
    }
}

// ------------------------------------------------------------ ring core --

/// The static shape of one barrier-stepped ring collective: per-edge
/// transfer times grouped into shared-WAN bulk flows plus the intra-region
/// delay floor. Shared by the placement executor and the dedicated
/// all-reduce validation run.
pub(crate) struct RingProfile {
    /// Per ring link `k` (`nodes[k] → nodes[k+1 mod n]`): transfer ms.
    pub edge_ms: Vec<f64>,
    /// Distinct inter-region pairs with the pacing (max) edge time each —
    /// one bulk-flow occupancy per pair per step.
    pub wan_flows: Vec<(usize, f64)>,
    /// Slowest intra-region edge (pure delay, dedicated local fabric).
    pub intra_max_ms: f64,
    /// Steps of one all-reduce: `2(n−1)` (0 for n ≤ 1).
    pub steps: usize,
    /// Σ edge transfer times (per-step traffic attribution).
    pub sum_edge_ms: f64,
}

impl RingProfile {
    /// Build the profile for an all-reduce of `bytes` over `nodes` in the
    /// given ring order. `None` if any ring edge is unreachable.
    pub(crate) fn build(fleet: &Fleet, nodes: &[usize], bytes: f64)
        -> Option<RingProfile>
    {
        let n = nodes.len();
        if n <= 1 {
            return Some(RingProfile {
                edge_ms: Vec::new(),
                wan_flows: Vec::new(),
                intra_max_ms: 0.0,
                steps: 0,
                sum_edge_ms: 0.0,
            });
        }
        let chunk = bytes / n as f64;
        let mut edge_ms = Vec::with_capacity(n);
        let mut wan_flows: Vec<(usize, f64)> = Vec::new();
        let mut intra_max_ms = 0.0f64;
        let mut sum_edge_ms = 0.0;
        for k in 0..n {
            let a = nodes[k];
            let b = nodes[(k + 1) % n];
            let ms = p2p_ms(fleet, a, b, chunk)?;
            sum_edge_ms += ms;
            let ra = fleet.machines[a].region;
            let rb = fleet.machines[b].region;
            if ra == rb {
                intra_max_ms = intra_max_ms.max(ms);
            } else {
                let pair = pair_index(ra, rb);
                match wan_flows.iter_mut().find(|(p, _)| *p == pair) {
                    Some((_, m)) => *m = m.max(ms),
                    None => wan_flows.push((pair, ms)),
                }
            }
            edge_ms.push(ms);
        }
        Some(RingProfile {
            edge_ms,
            wan_flows,
            intra_max_ms,
            steps: 2 * (n - 1),
            sum_edge_ms,
        })
    }

    /// Uncontended step duration: the slowest edge (bulk flows pace on
    /// their slowest member, intra edges are pure delay).
    pub(crate) fn step_ms(&self) -> f64 {
        self.wan_flows
            .iter()
            .map(|&(_, ms)| ms)
            .fold(self.intra_max_ms, f64::max)
    }
}

/// Unordered region pair → dense index into the link table.
fn pair_index(a: Region, b: Region) -> usize {
    let (lo, hi) = if a.index() <= b.index() {
        (a.index(), b.index())
    } else {
        (b.index(), a.index())
    };
    lo * Region::ALL.len() + hi
}

/// Outcome of one dedicated (contention-free) ring all-reduce — the
/// validation entry point behind [`super::simulate_ring_allreduce`].
pub(crate) struct RingRun {
    pub makespan_ms: f64,
    pub step_ms: Vec<f64>,
    pub link_busy_ms: Vec<f64>,
    pub events_processed: u64,
    pub trace: Trace,
}

/// Run one ring all-reduce alone on dedicated links, step-barriered,
/// emitting a [`TraceKind::RingStep`] record per completed link transfer.
pub(crate) fn run_ring_dedicated(fleet: &Fleet, nodes: &[usize], bytes: f64,
                                 with_trace: bool) -> Option<RingRun>
{
    let profile = RingProfile::build(fleet, nodes, bytes)?;
    let mut trace = if with_trace {
        // One RingStep record per link per step.
        Trace::enabled_with_capacity(profile.steps * profile.edge_ms.len())
    } else {
        Trace::disabled()
    };
    let mut link_busy_ms = vec![0.0f64; profile.edge_ms.len()];
    let mut step_ms = Vec::with_capacity(profile.steps);
    let mut engine: Engine<usize> = Engine::new();
    let step_dur = profile.step_ms();
    if profile.steps > 0 {
        engine.schedule(step_dur, 0);
    }
    let mut makespan = 0.0;
    while let Some(ev) = engine.next() {
        let step = ev.payload;
        let started = engine.now_ms() - step_dur;
        for (k, &ms) in profile.edge_ms.iter().enumerate() {
            link_busy_ms[k] += ms;
            trace.record(started + ms,
                         TraceKind::RingStep { link: k, step, dur_ms: ms });
        }
        step_ms.push(step_dur);
        makespan = engine.now_ms();
        if step + 1 < profile.steps {
            engine.schedule_in(step_dur, step + 1);
        }
    }
    Some(RingRun {
        makespan_ms: makespan,
        step_ms,
        link_busy_ms,
        events_processed: engine.events_processed,
        trace,
    })
}

// ----------------------------------------------------- placement lowering --

/// Where a pipeline boundary's traffic goes.
#[derive(Clone, Copy, Debug)]
enum BoundaryKind {
    /// Intra-region: private per-(task, boundary) serialization.
    Private(usize),
    /// Inter-region: the shared WAN link of the region pair.
    Wan(usize),
}

/// Per-task runtime state of a lowered pipeline.
struct PipeRt {
    stages: Vec<usize>,
    fwd_ms: Vec<f64>,
    bwd_ms: Vec<f64>,
    link_ms: Vec<f64>,
    boundary: Vec<BoundaryKind>,
    k: usize,
    fwd_done_at_last: usize,
    bwd_done_at_first: usize,
    bwd_completed: Vec<bool>,
}

/// Per-task runtime state of a lowered collective (DP / TP).
struct CollRt {
    /// One all-reduce is `profile.steps` barrier-stepped rounds; DP runs
    /// one all-reduce, TP runs `layers × 4`.
    profile: RingProfile,
    total_steps: usize,
}

enum TaskRt {
    Skipped,
    Collective(CollRt),
    Pipeline(PipeRt),
}

#[derive(Clone, Copy, Debug)]
enum Ev {
    /// Compute barrier of a collective task cleared.
    ComputeDone { task: usize },
    /// Barrier-step `step` of a collective task completed.
    Step { task: usize, step: usize },
    /// Activation for microbatch `mb` arrived at `stage` (compute next).
    Fwd { task: usize, stage: usize, mb: usize },
    Bwd { task: usize, stage: usize, mb: usize },
    /// Stage `stage` finished computing `mb`: its outbound (fwd) /
    /// inbound-boundary (bwd) transfer becomes *ready* now. Links are
    /// only ever occupied at readiness time, never reserved into the
    /// future — the queue discipline stays work-conserving under
    /// cross-task contention.
    FwdXfer { task: usize, stage: usize, mb: usize },
    BwdXfer { task: usize, stage: usize, mb: usize },
    Fail { machine: usize },
}

/// Execute one training iteration of every task of `placement`
/// concurrently on `fleet`, honoring shared-WAN and machine contention.
/// `workload[t]` must be the model of `placement.per_task[t]`.
pub fn execute_placement(fleet: &Fleet, workload: &[ModelSpec],
                         placement: &Placement) -> ClusterExecution
{
    execute_placement_with(fleet, workload, placement,
                           ExecOptions::default())
}

/// Reusable buffers of one `execute_placement` call: the event-queue
/// storage and the resource/accounting vectors. The simulated cost
/// backend executes one placement per (scenario × planner) cell and the
/// micro benches execute thousands; recycling the payload vec and the
/// flat accounting arrays through a thread-local keeps the hot loop
/// allocation-free after warm-up. Every field is fully re-initialized
/// per call, so reuse cannot leak state across runs (determinism gate).
#[derive(Default)]
struct ExecScratch {
    events: Vec<super::engine::Event<Ev>>,
    machines: Vec<Resource>,
    links: Vec<Resource>,
    /// Flattened `[n_tasks × fleet.len()]` per-task machine busy time.
    machine_busy: Vec<f64>,
    comm_busy: Vec<f64>,
    finish: Vec<f64>,
}

thread_local! {
    static SCRATCH: std::cell::RefCell<ExecScratch> =
        std::cell::RefCell::new(ExecScratch::default());
}

/// [`execute_placement`] with failure injection / tracing options.
pub fn execute_placement_with(fleet: &Fleet, workload: &[ModelSpec],
                              placement: &Placement, opts: ExecOptions)
    -> ClusterExecution
{
    assert_eq!(workload.len(), placement.n_tasks(),
               "workload/placement task count mismatch");
    let n_tasks = workload.len();
    let n_machines = fleet.len();
    let n_regions = Region::ALL.len();

    let mut scratch =
        SCRATCH.with(|s| std::mem::take(&mut *s.borrow_mut()));
    let mut engine: Engine<Ev> =
        Engine::with_spare(std::mem::take(&mut scratch.events));
    let machines = &mut scratch.machines;
    machines.clear();
    machines.resize(n_machines, Resource::default());
    let links = &mut scratch.links;
    links.clear();
    links.resize(n_regions * n_regions, Resource::default());
    let mut private_links: Vec<Vec<Resource>> =
        (0..n_tasks).map(|_| Vec::new()).collect();
    let mut trace = if opts.with_trace {
        Trace::enabled_with_capacity(trace_capacity(placement))
    } else {
        Trace::disabled()
    };

    // Per-task accounting (machine busy time is a flat
    // `[task × machine]` matrix — one allocation, recycled).
    let machine_busy = &mut scratch.machine_busy;
    machine_busy.clear();
    machine_busy.resize(n_tasks * n_machines, 0.0);
    let comm_busy = &mut scratch.comm_busy;
    comm_busy.clear();
    comm_busy.resize(n_tasks, 0.0);
    let finish = &mut scratch.finish;
    finish.clear();
    finish.resize(n_tasks, f64::INFINITY);
    let mut active = 0usize;

    // Lower every feasible task at t = 0, placement order. Feasibility is
    // the *analytic* models' verdict, so the two backends never disagree
    // on which (task × placement) cells are executable at all.
    let mut runtime: Vec<TaskRt> = Vec::with_capacity(n_tasks);
    for (t, model) in workload.iter().enumerate() {
        let a_cost = placement.cost(fleet, model, t);
        if !a_cost.is_feasible() {
            runtime.push(TaskRt::Skipped);
            continue;
        }
        active += 1;
        match &placement.per_task[t] {
            TaskPlacement::Replicated { participants } => {
                let comp = a_cost.comp_ms;
                let mut barrier = 0.0f64;
                for &m in participants {
                    let done = machines[m].occupy(0.0, comp);
                    machine_busy[t * n_machines + m] += comp;
                    barrier = barrier.max(done);
                }
                let profile =
                    RingProfile::build(fleet, participants,
                                       model.grad_bytes())
                        .expect("feasible DP ring");
                let total_steps = profile.steps;
                runtime.push(TaskRt::Collective(CollRt { profile,
                                                         total_steps }));
                engine.schedule(barrier, Ev::ComputeDone { task: t });
            }
            TaskPlacement::TensorSharded { group } => {
                let comp = a_cost.comp_ms;
                let mut barrier = 0.0f64;
                for &m in group {
                    let done = machines[m].occupy(0.0, comp);
                    machine_busy[t * n_machines + m] += comp;
                    barrier = barrier.max(done);
                }
                let profile = RingProfile::build(
                    fleet, group, model.activation_bytes(model.batch))
                    .expect("feasible TP ring");
                let per_layer = crate::parallel::tensor_parallel
                    ::ALLREDUCES_PER_LAYER as usize;
                let total_steps = model.layers * per_layer * profile.steps;
                runtime.push(TaskRt::Collective(CollRt { profile,
                                                         total_steps }));
                engine.schedule(barrier, Ev::ComputeDone { task: t });
            }
            TaskPlacement::PipelineStages { stages, layers, microbatches }
            | TaskPlacement::Grouped { chain: stages, layers,
                                       microbatches, .. } => {
                let rt = lower_pipeline(fleet, stages, layers,
                                        *microbatches, model,
                                        &mut private_links[t],
                                        opts.dedicated_links);
                for mb in 0..rt.k {
                    engine.schedule(0.0, Ev::Fwd { task: t, stage: 0, mb });
                }
                runtime.push(TaskRt::Pipeline(rt));
            }
        }
    }

    if let Some(f) = opts.failure {
        engine.schedule(f.at_ms, Ev::Fail { machine: f.machine });
    }

    let mut failure: Option<FailureOutcome> = None;
    while let Some(ev) = engine.next() {
        if active == 0 {
            break;
        }
        let now = ev.time_ms;
        match ev.payload {
            Ev::Fail { machine } => {
                let victim_task = (0..n_tasks).find(|&t| {
                    !matches!(runtime[t], TaskRt::Skipped)
                        && finish[t].is_infinite()
                        && placement.machines(t).contains(&machine)
                });
                if let Some(t) = victim_task {
                    let completed = match &runtime[t] {
                        TaskRt::Pipeline(p) => {
                            p.bwd_completed.iter().filter(|&&d| d).count()
                        }
                        _ => 0,
                    };
                    failure = Some(FailureOutcome {
                        at_ms: now,
                        machine,
                        completed_microbatches: completed,
                    });
                    trace.record(now, TraceKind::Failure { machine });
                    break;
                }
            }
            Ev::ComputeDone { task } | Ev::Step { task, step: _ } => {
                // Advance the collective to its next barrier step (or
                // finish). The step index lives in the event only for
                // debugging; the runtime tracks progress itself via the
                // scheduled chain, so `next_step` derives from the event.
                let step = match ev.payload {
                    Ev::Step { step, .. } => step + 1,
                    _ => 0,
                };
                let TaskRt::Collective(c) = &runtime[task] else {
                    unreachable!("collective event for non-collective task")
                };
                if step >= c.total_steps {
                    finish[task] = now;
                    active -= 1;
                    if active == 0 {
                        break;
                    }
                } else {
                    let mut barrier = now + c.profile.intra_max_ms;
                    for &(pair, ms) in &c.profile.wan_flows {
                        let done = links[pair].occupy(now, ms);
                        barrier = barrier.max(done);
                    }
                    comm_busy[task] += c.profile.sum_edge_ms;
                    engine.schedule(barrier, Ev::Step { task, step });
                }
            }
            Ev::Fwd { task, stage, mb } => {
                let TaskRt::Pipeline(p) = &mut runtime[task] else {
                    unreachable!("pipeline event for non-pipeline task")
                };
                let m = p.stages[stage];
                let done = machines[m].occupy(now, p.fwd_ms[stage]);
                machine_busy[task * n_machines + m] += p.fwd_ms[stage];
                trace.record(done, TraceKind::Compute {
                    stage, mb, backward: false, dur_ms: p.fwd_ms[stage] });
                if stage + 1 < p.stages.len() {
                    engine.schedule(done, Ev::FwdXfer { task, stage, mb });
                } else {
                    p.fwd_done_at_last += 1;
                    if p.fwd_done_at_last == p.k {
                        // GPipe flush: backward after the full forward
                        // wave, last microbatch first.
                        let last = p.stages.len() - 1;
                        for b in (0..p.k).rev() {
                            engine.schedule(done, Ev::Bwd { task,
                                                            stage: last,
                                                            mb: b });
                        }
                    }
                }
            }
            Ev::FwdXfer { task, stage, mb } => {
                let TaskRt::Pipeline(p) = &runtime[task] else {
                    unreachable!("pipeline event for non-pipeline task")
                };
                let ms = p.link_ms[stage];
                let arr = match p.boundary[stage] {
                    BoundaryKind::Private(i) => {
                        private_links[task][i].occupy(now, ms)
                    }
                    BoundaryKind::Wan(pair) => links[pair].occupy(now, ms),
                };
                comm_busy[task] += ms;
                trace.record(arr, TraceKind::Transfer {
                    boundary: stage, mb, backward: false, dur_ms: ms });
                engine.schedule(arr, Ev::Fwd { task, stage: stage + 1,
                                               mb });
            }
            Ev::Bwd { task, stage, mb } => {
                let TaskRt::Pipeline(p) = &mut runtime[task] else {
                    unreachable!("pipeline event for non-pipeline task")
                };
                let m = p.stages[stage];
                let done = machines[m].occupy(now, p.bwd_ms[stage]);
                machine_busy[task * n_machines + m] += p.bwd_ms[stage];
                trace.record(done, TraceKind::Compute {
                    stage, mb, backward: true, dur_ms: p.bwd_ms[stage] });
                if stage > 0 {
                    engine.schedule(done, Ev::BwdXfer { task, stage, mb });
                } else {
                    p.bwd_completed[mb] = true;
                    p.bwd_done_at_first += 1;
                    if p.bwd_done_at_first == p.k {
                        finish[task] = done;
                        active -= 1;
                        if active == 0 {
                            break;
                        }
                    }
                }
            }
            Ev::BwdXfer { task, stage, mb } => {
                let TaskRt::Pipeline(p) = &runtime[task] else {
                    unreachable!("pipeline event for non-pipeline task")
                };
                let ms = p.link_ms[stage - 1];
                let arr = match p.boundary[stage - 1] {
                    BoundaryKind::Private(i) => {
                        private_links[task][i].occupy(now, ms)
                    }
                    BoundaryKind::Wan(pair) => links[pair].occupy(now, ms),
                };
                comm_busy[task] += ms;
                trace.record(arr, TraceKind::Transfer {
                    boundary: stage - 1, mb, backward: true, dur_ms: ms });
                engine.schedule(arr, Ev::Bwd { task, stage: stage - 1,
                                               mb });
            }
        }
    }

    // ------------------------------------------------------- reporting --
    let feasible: Vec<usize> = (0..n_tasks)
        .filter(|&t| !matches!(runtime[t], TaskRt::Skipped))
        .collect();
    let makespan = if feasible.is_empty() {
        0.0
    } else {
        feasible.iter().map(|&t| finish[t]).fold(0.0f64, f64::max)
    };
    let earliest = feasible
        .iter()
        .map(|&t| finish[t])
        .fold(f64::INFINITY, f64::min);
    let straggler_wait_ms =
        if makespan.is_finite() && earliest.is_finite() && feasible.len() > 1
        {
            makespan - earliest
        } else {
            0.0
        };

    let tasks: Vec<TaskExec> = (0..n_tasks)
        .map(|t| {
            if matches!(runtime[t], TaskRt::Skipped) {
                return TaskExec {
                    cost: IterCost::infeasible(),
                    finish_ms: f64::INFINITY,
                    comp_busy_ms: 0.0,
                    comm_busy_ms: 0.0,
                };
            }
            let busy_row =
                &machine_busy[t * n_machines..(t + 1) * n_machines];
            let comp_busy_ms: f64 = busy_row.iter().sum();
            let pacing = busy_row.iter().cloned().fold(0.0f64, f64::max);
            let cost = if finish[t].is_finite() {
                IterCost { comp_ms: pacing, comm_ms: finish[t] - pacing }
            } else {
                IterCost::infeasible()
            };
            TaskExec {
                cost,
                finish_ms: finish[t],
                comp_busy_ms,
                comm_busy_ms: comm_busy[t],
            }
        })
        .collect();

    let mut link_uses = Vec::new();
    for (i, &a) in Region::ALL.iter().enumerate() {
        for (j, &b) in Region::ALL.iter().enumerate().skip(i + 1) {
            let busy = links[i * n_regions + j].busy_ms();
            if busy > 0.0 {
                let utilization = if makespan.is_finite() && makespan > 0.0 {
                    busy / makespan
                } else {
                    0.0
                };
                link_uses.push(LinkUse { a, b, busy_ms: busy,
                                         utilization });
            }
        }
    }

    let events_processed = engine.events_processed;
    // Hand the queue storage and accounting buffers back for the next
    // call on this thread.
    scratch.events = engine.into_spare();
    SCRATCH.with(|s| *s.borrow_mut() = scratch);

    ClusterExecution {
        tasks,
        report: ExecReport {
            makespan_ms: makespan,
            straggler_wait_ms,
            links: link_uses,
            events_processed,
        },
        failure,
        trace,
    }
}

/// Upper bound on the trace records one placement execution emits: per
/// pipeline microbatch, a compute + transfer record per stage in each
/// direction; collectives record nothing here; plus the failure record.
fn trace_capacity(placement: &Placement) -> usize {
    1 + placement
        .per_task
        .iter()
        .map(|p| match p {
            TaskPlacement::PipelineStages { stages, microbatches, .. }
            | TaskPlacement::Grouped { chain: stages, microbatches, .. } => {
                4 * stages.len() * *microbatches
            }
            _ => 0,
        })
        .sum::<usize>()
}

/// Lower one GPipe plan: per-stage fwd/bwd compute times (6×params split
/// 2 fwd : 4 bwd, exactly as `parallel::pipeline`), per-boundary transfer
/// times, and the boundary routing (private intra-region serialization
/// vs the shared WAN link; `dedicated` forces every boundary private —
/// the single-schedule validation mode).
fn lower_pipeline(fleet: &Fleet, stages: &[usize], layers: &[usize],
                  microbatches: usize, model: &ModelSpec,
                  private: &mut Vec<Resource>, dedicated: bool) -> PipeRt
{
    let s = stages.len();
    let k = microbatches;
    let micro_batch =
        ((model.batch as f64 / k as f64).ceil() as usize).max(1);
    let micro_tokens = (micro_batch * model.seq_len) as f64;
    let act_bytes = model.activation_bytes(micro_batch);

    let mut fwd_ms = Vec::with_capacity(s);
    let mut bwd_ms = Vec::with_capacity(s);
    for (i, &m) in stages.iter().enumerate() {
        let frac = layers[i] as f64 / model.layers as f64;
        let flops = crate::models::FLOPS_PER_TOKEN_FACTOR
            * model.params
            * frac
            * micro_tokens;
        let total = flops / (fleet.machines[m].total_tflops() * 1e12) * 1e3;
        fwd_ms.push(total / 3.0);
        bwd_ms.push(total * 2.0 / 3.0);
    }
    let mut link_ms = Vec::with_capacity(s.saturating_sub(1));
    let mut boundary = Vec::with_capacity(s.saturating_sub(1));
    for i in 0..s.saturating_sub(1) {
        let a = stages[i];
        let b = stages[i + 1];
        link_ms.push(p2p_ms(fleet, a, b, act_bytes)
            .expect("feasible pipeline boundary"));
        let ra = fleet.machines[a].region;
        let rb = fleet.machines[b].region;
        if dedicated || ra == rb {
            private.push(Resource::default());
            boundary.push(BoundaryKind::Private(private.len() - 1));
        } else {
            boundary.push(BoundaryKind::Wan(pair_index(ra, rb)));
        }
    }
    PipeRt {
        stages: stages.to_vec(),
        fwd_ms,
        bwd_ms,
        link_ms,
        boundary,
        k,
        fwd_done_at_last: 0,
        bwd_done_at_first: 0,
        bwd_completed: vec![false; k],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::ClusterGraph;
    use crate::parallel::{data_parallel_cost, tensor_parallel_cost};
    use crate::planner::{HulkPlanner, PlanContext, Planner,
                         HulkSplitterKind, SystemBPlanner};

    fn dp_placement(participants: Vec<usize>) -> Placement {
        Placement {
            per_task: vec![TaskPlacement::Replicated { participants }],
        }
    }

    #[test]
    fn lone_replicated_task_matches_the_analytic_closed_form() {
        let fleet = Fleet::paper_evaluation(0);
        let model = ModelSpec::bert_large();
        let participants: Vec<usize> = (0..8).collect();
        let analytic = data_parallel_cost(&fleet, &participants, &model);
        let run = execute_placement(&fleet, &[model],
                                    &dp_placement(participants));
        let sim = run.tasks[0].cost;
        assert!((sim.comp_ms - analytic.comp_ms).abs()
                    / analytic.comp_ms < 1e-9);
        assert!((sim.comm_ms - analytic.comm_ms).abs()
                    / analytic.comm_ms < 1e-9);
        assert_eq!(run.report.straggler_wait_ms, 0.0);
    }

    #[test]
    fn lone_tensor_task_matches_the_analytic_closed_form() {
        let fleet = Fleet::paper_toy(0);
        let model = ModelSpec::bert_large();
        let group: Vec<usize> = (0..fleet.len()).collect();
        let analytic = tensor_parallel_cost(&fleet, &group, &model);
        let placement = Placement {
            per_task: vec![TaskPlacement::TensorSharded { group }],
        };
        let run = execute_placement(&fleet, &[model], &placement);
        let sim = run.tasks[0].cost;
        assert!((sim.total_ms() - analytic.total_ms()).abs()
                    / analytic.total_ms() < 1e-9,
                "sim {} vs analytic {}", sim.total_ms(),
                analytic.total_ms());
    }

    #[test]
    fn infeasible_tasks_stay_infeasible_and_cost_no_events() {
        let fleet = Fleet::paper_evaluation(0);
        let model = ModelSpec::opt_175b(); // fits no single machine
        let run = execute_placement(&fleet, &[model],
                                    &dp_placement(vec![]));
        assert!(!run.tasks[0].cost.is_feasible());
        assert_eq!(run.report.events_processed, 0);
        assert_eq!(run.report.makespan_ms, 0.0);
    }

    #[test]
    fn shared_resources_make_concurrent_tasks_slower_than_lone_ones() {
        // Two DP tasks on the SAME Beijing+California pair: they queue
        // on the machines and on the shared trans-Pacific link, so the
        // second task must be well slower than a lone run, and the
        // pacific link shows up in the link report.
        let fleet = Fleet::paper_evaluation(0);
        let beijing = (0..fleet.len())
            .find(|&i| fleet.machines[i].region == Region::Beijing)
            .unwrap();
        let california = (0..fleet.len())
            .find(|&i| fleet.machines[i].region == Region::California)
            .unwrap();
        let straddle: Vec<usize> = vec![beijing, california];
        let model = ModelSpec::bert_large();
        let lone = execute_placement(&fleet, &[model.clone()],
                                     &dp_placement(straddle.clone()));
        let both = execute_placement(
            &fleet,
            &[model.clone(), model],
            &Placement {
                per_task: vec![
                    TaskPlacement::Replicated {
                        participants: straddle.clone(),
                    },
                    TaskPlacement::Replicated { participants: straddle },
                ],
            },
        );
        let lone_total = lone.tasks[0].cost.total_ms();
        let slower = both.tasks[1].cost.total_ms();
        assert!(slower > lone_total * 1.5,
                "no contention visible: lone {lone_total} vs {slower}");
        assert!(both.report.straggler_wait_ms >= 0.0);
        assert!(both
            .report
            .links
            .iter()
            .any(|l| l.connects(Region::Beijing, Region::California)
                && l.utilization > 0.0));
    }

    #[test]
    fn whole_hulk_placement_executes_with_disjoint_groups() {
        let fleet = Fleet::paper_evaluation(0);
        let graph = ClusterGraph::from_fleet(&fleet);
        let mut wl = ModelSpec::paper_four();
        ModelSpec::sort_largest_first(&mut wl);
        let ctx = PlanContext::new(&fleet, &graph, &wl,
                                   HulkSplitterKind::Oracle);
        let placement = HulkPlanner.plan(&ctx).unwrap();
        let run = execute_placement(&fleet, &wl, &placement);
        assert!(run.report.makespan_ms.is_finite());
        assert!(run.report.events_processed > 0);
        for (t, task) in run.tasks.iter().enumerate() {
            assert!(task.cost.is_feasible(), "task {t} infeasible");
            assert!(task.cost.comm_ms >= 0.0 && task.cost.comp_ms > 0.0);
            assert!(task.finish_ms <= run.report.makespan_ms + 1e-9);
        }
        // Disjoint groups ⇒ the makespan is the slowest task, and the
        // straggler wait is the gap to the fastest.
        let fastest = run
            .tasks
            .iter()
            .map(|t| t.finish_ms)
            .fold(f64::INFINITY, f64::min);
        assert!((run.report.straggler_wait_ms
                 - (run.report.makespan_ms - fastest))
                    .abs() < 1e-9);
    }

    #[test]
    fn system_b_contends_harder_than_hulk_on_the_same_workload() {
        // Every System B task pipelines over the whole fleet in id order:
        // under whole-placement execution its tasks queue on machines and
        // WAN links, so its makespan must exceed Hulk's (disjoint
        // regional groups) by a wide margin.
        let fleet = Fleet::paper_evaluation(0);
        let graph = ClusterGraph::from_fleet(&fleet);
        let mut wl = ModelSpec::paper_four();
        ModelSpec::sort_largest_first(&mut wl);
        let ctx = PlanContext::new(&fleet, &graph, &wl,
                                   HulkSplitterKind::Oracle);
        let hulk = execute_placement(&fleet, &wl,
                                     &HulkPlanner.plan(&ctx).unwrap());
        let b = execute_placement(&fleet, &wl,
                                  &SystemBPlanner.plan(&ctx).unwrap());
        assert!(b.report.makespan_ms > hulk.report.makespan_ms,
                "B {} vs Hulk {}", b.report.makespan_ms,
                hulk.report.makespan_ms);
    }

    #[test]
    fn failure_halts_a_participating_task() {
        let fleet = Fleet::paper_toy(0);
        let model = ModelSpec::gpt2_xl();
        let plan = crate::parallel::PipelinePlan::proportional(
            &fleet, vec![0, 1, 2, 3], &model);
        let placement = Placement {
            per_task: vec![TaskPlacement::PipelineStages {
                stages: plan.stages.clone(),
                layers: plan.layers.clone(),
                microbatches: plan.microbatches,
            }],
        };
        let healthy = execute_placement(&fleet, &[model.clone()],
                                        &placement);
        let at_ms = healthy.report.makespan_ms * 0.4;
        let run = execute_placement_with(&fleet, &[model], &placement,
                                         ExecOptions {
                                             failure: Some(FailurePlan {
                                                 at_ms,
                                                 machine: plan.stages[1],
                                             }),
                                             ..ExecOptions::default()
                                         });
        let outcome = run.failure.expect("failure observed");
        assert_eq!(outcome.machine, plan.stages[1]);
        assert!((outcome.at_ms - at_ms).abs() < 1e-9);
        assert!(run.report.makespan_ms.is_infinite());
        assert!(!run.tasks[0].cost.is_feasible());
    }

    #[test]
    fn scratch_reuse_across_calls_changes_no_output() {
        // Back-to-back executions on one thread share the recycled
        // buffers; every observable field must be bit-identical, and a
        // smaller follow-up run must not see the larger run's state.
        let fleet = Fleet::paper_evaluation(0);
        let graph = ClusterGraph::from_fleet(&fleet);
        let mut wl = ModelSpec::paper_four();
        ModelSpec::sort_largest_first(&mut wl);
        let ctx = PlanContext::new(&fleet, &graph, &wl,
                                   HulkSplitterKind::Oracle);
        let placement = HulkPlanner.plan(&ctx).unwrap();
        let first = execute_placement(&fleet, &wl, &placement);
        let small_wl = vec![ModelSpec::bert_large()];
        let small = execute_placement(
            &fleet,
            &small_wl,
            &dp_placement((0..4).collect()),
        );
        assert_eq!(small.tasks.len(), 1);
        assert!(small.tasks[0].cost.is_feasible());
        let again = execute_placement(&fleet, &wl, &placement);
        assert_eq!(first.report.makespan_ms, again.report.makespan_ms);
        assert_eq!(first.report.events_processed,
                   again.report.events_processed);
        for (a, b) in first.tasks.iter().zip(&again.tasks) {
            assert_eq!(a.cost, b.cost);
            assert_eq!(a.finish_ms, b.finish_ms);
            assert_eq!(a.comp_busy_ms, b.comp_busy_ms);
            assert_eq!(a.comm_busy_ms, b.comm_busy_ms);
        }
        assert_eq!(first.report.links.len(), again.report.links.len());
    }

    #[test]
    fn ring_profile_groups_wan_flows_and_paces_on_the_slowest_edge() {
        let fleet = Fleet::paper_toy(0);
        let nodes: Vec<usize> = (0..4).collect();
        let profile = RingProfile::build(&fleet, &nodes, 4e6).unwrap();
        assert_eq!(profile.edge_ms.len(), 4);
        assert_eq!(profile.steps, 6);
        let max_edge =
            profile.edge_ms.iter().cloned().fold(0.0f64, f64::max);
        assert!((profile.step_ms() - max_edge).abs() < 1e-12);
        // Σ flows over pairs never exceeds the per-edge sum.
        let flow_sum: f64 =
            profile.wan_flows.iter().map(|&(_, ms)| ms).sum();
        assert!(flow_sum <= profile.sum_edge_ms + 1e-12);
    }
}
