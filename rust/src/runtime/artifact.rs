//! Artifact manifest: the shape contract between `aot.py` and the Rust
//! runtime (`artifacts/manifest.kv`).

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::kv::KvFile;

/// Parsed `manifest.kv` + resolved artifact paths.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    /// Node slots in the compiled GCN (padding target).
    pub n: usize,
    /// Feature dim — must equal `graph::FEATURE_DIM`.
    pub f: usize,
    pub h: usize,
    pub h2: usize,
    /// Task classes.
    pub c: usize,
    /// Flat parameter-vector length.
    pub p: usize,
    pub forward_hlo: PathBuf,
    pub train_step_hlo: PathBuf,
    pub init_params: PathBuf,
}

impl Manifest {
    /// Load and validate `dir/manifest.kv`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let kv = KvFile::load(&dir.join("manifest.kv"))?;
        let format = kv.get("format")?;
        if format != "1" {
            bail!("unsupported manifest format {format:?}");
        }
        let m = Manifest {
            dir: dir.to_path_buf(),
            n: kv.get_usize("n")?,
            f: kv.get_usize("f")?,
            h: kv.get_usize("h")?,
            h2: kv.get_usize("h2")?,
            c: kv.get_usize("c")?,
            p: kv.get_usize("p")?,
            forward_hlo: dir.join(kv.get("forward")?),
            train_step_hlo: dir.join(kv.get("train_step")?),
            init_params: dir.join(kv.get("init_params")?),
        };
        if m.f != crate::graph::FEATURE_DIM {
            bail!(
                "manifest feature dim {} != graph::FEATURE_DIM {} — \
                 regenerate artifacts",
                m.f,
                crate::graph::FEATURE_DIM
            );
        }
        for path in [&m.forward_hlo, &m.train_step_hlo, &m.init_params] {
            if !path.exists() {
                bail!("artifact missing: {} (run `make artifacts`)",
                      path.display());
            }
        }
        Ok(m)
    }

    /// Default artifact directory: `$HULK_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("HULK_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    /// Load the initial parameter vector (little-endian f32).
    pub fn load_init_params(&self) -> Result<Vec<f32>> {
        let bytes = std::fs::read(&self.init_params).with_context(|| {
            format!("reading {}", self.init_params.display())
        })?;
        if bytes.len() != self.p * 4 {
            bail!(
                "init_params has {} bytes, expected {} ({} f32)",
                bytes.len(),
                self.p * 4,
                self.p
            );
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn manifest_text() -> &'static str {
        "format 1\nn 64\nf 18\nh 256\nh2 128\nc 8\np 174216\n\
         forward gcn_forward.hlo.txt\ntrain_step gcn_train_step.hlo.txt\n\
         init_params init_params.f32\n"
    }

    #[test]
    fn loads_real_artifacts_when_present() {
        // Integration-style: if `make artifacts` has run, parse the real
        // manifest. Skipped silently otherwise (unit tests must not
        // require the python toolchain).
        let dir = Path::new("artifacts");
        if !dir.join("manifest.kv").exists() {
            return;
        }
        let m = Manifest::load(dir).unwrap();
        assert_eq!(m.f, crate::graph::FEATURE_DIM);
        let params = m.load_init_params().unwrap();
        assert_eq!(params.len(), m.p);
        // Glorot init: non-trivial values in a sane range.
        assert!(params.iter().any(|&v| v != 0.0));
        assert!(params.iter().all(|v| v.abs() < 1.0));
    }

    #[test]
    fn missing_file_reports_helpful_error() {
        let tmp = std::env::temp_dir().join("hulk_manifest_test_missing");
        std::fs::create_dir_all(&tmp).unwrap();
        std::fs::write(tmp.join("manifest.kv"), manifest_text()).unwrap();
        let err = Manifest::load(&tmp).unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
        std::fs::remove_dir_all(&tmp).ok();
    }

    #[test]
    fn init_param_length_is_validated() {
        let tmp = std::env::temp_dir().join("hulk_manifest_test_len");
        std::fs::create_dir_all(&tmp).unwrap();
        std::fs::write(tmp.join("manifest.kv"), manifest_text()).unwrap();
        for name in ["gcn_forward.hlo.txt", "gcn_train_step.hlo.txt"] {
            std::fs::write(tmp.join(name), "HloModule fake").unwrap();
        }
        let mut f = std::fs::File::create(tmp.join("init_params.f32")).unwrap();
        f.write_all(&[0u8; 16]).unwrap(); // wrong length
        drop(f);
        let m = Manifest::load(&tmp).unwrap();
        assert!(m.load_init_params().is_err());
        std::fs::remove_dir_all(&tmp).ok();
    }

    #[test]
    fn wrong_format_rejected() {
        let tmp = std::env::temp_dir().join("hulk_manifest_test_fmt");
        std::fs::create_dir_all(&tmp).unwrap();
        std::fs::write(tmp.join("manifest.kv"), "format 2\n").unwrap();
        assert!(Manifest::load(&tmp).is_err());
        std::fs::remove_dir_all(&tmp).ok();
    }
}
