//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`) produced
//! by `python/compile/aot.py` and executes them on the CPU PJRT client.
//! This is the ONLY place Rust touches XLA; everything above works with
//! plain `Vec<f32>` tensors.
//!
//! Interchange is HLO *text* (see aot.py / DESIGN.md): the text parser
//! reassigns instruction ids, avoiding the 64-bit-id protos that
//! xla_extension 0.5.1 rejects.

pub mod artifact;
pub mod client;
pub mod literal;

pub use artifact::Manifest;
pub use client::GcnRuntime;
