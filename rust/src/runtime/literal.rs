//! Literal marshalling helpers: `Vec<f32>`/`Vec<i32>` ⇄ `xla::Literal`
//! with explicit shapes.

use anyhow::{bail, Result};

/// f32 literal of the given dims (row-major).
pub fn f32_literal(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let expect: i64 = dims.iter().product();
    if data.len() as i64 != expect {
        bail!("literal data {} != dims {:?}", data.len(), dims);
    }
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// i32 literal of the given dims.
pub fn i32_literal(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let expect: i64 = dims.iter().product();
    if data.len() as i64 != expect {
        bail!("literal data {} != dims {:?}", data.len(), dims);
    }
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Extract a scalar f32 from a rank-0 or single-element literal.
pub fn scalar_f32(lit: &xla::Literal) -> Result<f32> {
    let v = lit.to_vec::<f32>()?;
    match v.as_slice() {
        [x] => Ok(*x),
        _ => bail!("expected scalar, got {} elements", v.len()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let lit = f32_literal(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(lit.element_count(), 4);
    }

    #[test]
    fn i32_roundtrip() {
        let lit = i32_literal(&[7, 8, 9], &[3]).unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![7, 8, 9]);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(f32_literal(&[1.0, 2.0], &[3]).is_err());
        assert!(i32_literal(&[1], &[2, 2]).is_err());
    }

    #[test]
    fn scalar_extraction() {
        let lit = f32_literal(&[42.0], &[1]).unwrap();
        assert_eq!(scalar_f32(&lit).unwrap(), 42.0);
        let not_scalar = f32_literal(&[1.0, 2.0], &[2]).unwrap();
        assert!(scalar_f32(&not_scalar).is_err());
    }
}
