//! The GCN runtime: PJRT CPU client + compiled executables for the two
//! artifact entry points (forward, train_step).
//!
//! Executables are compiled once and cached; the training loop keeps
//! parameter/optimizer state as returned literals and feeds them back,
//! so the Python toolchain is never touched after `make artifacts`.

use std::path::Path;

use anyhow::{Context, Result};

use super::artifact::Manifest;
use super::literal::{f32_literal, i32_literal, scalar_f32};

/// Loaded GCN runtime.
pub struct GcnRuntime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    forward_exe: xla::PjRtLoadedExecutable,
    train_exe: xla::PjRtLoadedExecutable,
}

/// Output of one training step.
#[derive(Debug)]
pub struct StepOutput {
    pub loss: f32,
    pub acc: f32,
}

/// Mutable training state owned by the Rust driver (flat vectors; the
/// layout is opaque here — `aot.py` defines it).
#[derive(Clone, Debug)]
pub struct TrainState {
    pub params: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub step: u32,
}

impl TrainState {
    pub fn fresh(init_params: Vec<f32>) -> TrainState {
        let p = init_params.len();
        TrainState { params: init_params, m: vec![0.0; p], v: vec![0.0; p],
                     step: 0 }
    }
}

impl GcnRuntime {
    /// Load artifacts from `dir`, compile both entry points on the CPU
    /// PJRT client.
    pub fn load(dir: &Path) -> Result<GcnRuntime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        let forward_exe = Self::compile(&client, &manifest.forward_hlo)?;
        let train_exe = Self::compile(&client, &manifest.train_step_hlo)?;
        Ok(GcnRuntime { manifest, client, forward_exe, train_exe })
    }

    fn compile(client: &xla::PjRtClient, hlo: &Path)
        -> Result<xla::PjRtLoadedExecutable>
    {
        let proto = xla::HloModuleProto::from_text_file(hlo)
            .with_context(|| format!("parsing HLO text {}", hlo.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        client
            .compile(&comp)
            .with_context(|| format!("compiling {}", hlo.display()))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Forward pass: class probabilities, row-major `[n, c]`.
    ///
    /// Inputs are padded tensors (`graph::ClusterGraph::padded_adj`,
    /// `graph::node_features`) of exactly the manifest's N/F.
    pub fn forward(&self, params: &[f32], adj: &[f32], feats: &[f32],
                   mask: &[f32]) -> Result<Vec<f32>>
    {
        let n = self.manifest.n as i64;
        let f = self.manifest.f as i64;
        let p = self.manifest.p as i64;
        let args = [
            f32_literal(params, &[p])?,
            f32_literal(adj, &[n, n])?,
            f32_literal(feats, &[n, f])?,
            f32_literal(mask, &[n])?,
        ];
        let result = self.forward_exe.execute(&args)?[0][0]
            .to_literal_sync()?;
        let probs = result.to_tuple1()?;
        Ok(probs.to_vec::<f32>()?)
    }

    /// One Adam step in place on `state`. Labels use class ids
    /// `0..manifest.c`; padded rows must have `mask = 0`.
    pub fn train_step(&self, state: &mut TrainState, adj: &[f32],
                      feats: &[f32], labels: &[i32], mask: &[f32],
                      lr: f32) -> Result<StepOutput>
    {
        let n = self.manifest.n as i64;
        let f = self.manifest.f as i64;
        let p = self.manifest.p as i64;
        state.step += 1;
        let args = [
            f32_literal(&state.params, &[p])?,
            f32_literal(&state.m, &[p])?,
            f32_literal(&state.v, &[p])?,
            f32_literal(&[state.step as f32], &[1])?,
            f32_literal(adj, &[n, n])?,
            f32_literal(feats, &[n, f])?,
            i32_literal(labels, &[n])?,
            f32_literal(mask, &[n])?,
            f32_literal(&[lr], &[1])?,
        ];
        let result =
            self.train_exe.execute(&args)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        anyhow::ensure!(parts.len() == 5, "train_step returned {} outputs",
                        parts.len());
        state.params = parts[0].to_vec::<f32>()?;
        state.m = parts[1].to_vec::<f32>()?;
        state.v = parts[2].to_vec::<f32>()?;
        Ok(StepOutput {
            loss: scalar_f32(&parts[3])?,
            acc: scalar_f32(&parts[4])?,
        })
    }
}

// Integration tests that exercise the real artifacts live in
// rust/tests/runtime_integration.rs (they require `make artifacts`).

impl GcnRuntime {
    /// Diagnostic: how many output buffers does the train executable
    /// produce? (1 = tuple root kept; 5 = auto-untupled.)
    pub fn probe_train_output_arity(&self, state: &mut TrainState,
                                    adj: &[f32], feats: &[f32],
                                    labels: &[i32], mask: &[f32])
        -> Result<usize>
    {
        let n = self.manifest.n as i64;
        let f = self.manifest.f as i64;
        let p = self.manifest.p as i64;
        state.step += 1;
        let args = [
            f32_literal(&state.params, &[p])?,
            f32_literal(&state.m, &[p])?,
            f32_literal(&state.v, &[p])?,
            f32_literal(&[state.step as f32], &[1])?,
            f32_literal(adj, &[n, n])?,
            f32_literal(feats, &[n, f])?,
            i32_literal(labels, &[n])?,
            f32_literal(mask, &[n])?,
            f32_literal(&[0.01f32], &[1])?,
        ];
        let outs = self.train_exe.execute(&args)?;
        Ok(outs[0].len())
    }
}

impl GcnRuntime {
    /// Expose the compiled train executable (perf probes).
    pub fn train_executable(&self) -> &xla::PjRtLoadedExecutable {
        &self.train_exe
    }
}

/// Hot-path training state: parameters and optimizer moments kept as XLA
/// literals so successive steps avoid the `Vec<f32>` ⇄ `Literal` copies
/// (§Perf: ~1.5 ms/step of a ~8 ms step on this host). Convert back to
/// `TrainState` (host vectors) only when inference needs the params.
pub struct LitTrainState {
    params: xla::Literal,
    m: xla::Literal,
    v: xla::Literal,
    pub step: u32,
}

/// Pre-marshalled per-graph input literals (graph tensors are reused
/// across epochs — build them once per dataset entry).
pub struct GraphLiterals {
    adj: xla::Literal,
    feats: xla::Literal,
    labels: xla::Literal,
    mask: xla::Literal,
}

impl GcnRuntime {
    /// Build the literal-resident state from host vectors.
    pub fn lit_state(&self, state: &TrainState) -> Result<LitTrainState> {
        let p = self.manifest.p as i64;
        Ok(LitTrainState {
            params: f32_literal(&state.params, &[p])?,
            m: f32_literal(&state.m, &[p])?,
            v: f32_literal(&state.v, &[p])?,
            step: state.step,
        })
    }

    /// Read the literal-resident state back into host vectors.
    pub fn host_state(&self, state: &LitTrainState) -> Result<TrainState> {
        Ok(TrainState {
            params: state.params.to_vec::<f32>()?,
            m: state.m.to_vec::<f32>()?,
            v: state.v.to_vec::<f32>()?,
            step: state.step,
        })
    }

    /// Pre-marshal a graph's tensors.
    pub fn graph_literals(&self, adj: &[f32], feats: &[f32], labels: &[i32],
                          mask: &[f32]) -> Result<GraphLiterals>
    {
        let n = self.manifest.n as i64;
        let f = self.manifest.f as i64;
        Ok(GraphLiterals {
            adj: f32_literal(adj, &[n, n])?,
            feats: f32_literal(feats, &[n, f])?,
            labels: i32_literal(labels, &[n])?,
            mask: f32_literal(mask, &[n])?,
        })
    }

    /// One Adam step on the literal-resident state (the hot path: no
    /// param/moment host round-trip).
    pub fn train_step_fast(&self, state: &mut LitTrainState,
                           graph: &GraphLiterals, lr: f32)
        -> Result<StepOutput>
    {
        state.step += 1;
        let step_lit = f32_literal(&[state.step as f32], &[1])?;
        let lr_lit = f32_literal(&[lr], &[1])?;
        let args: [&xla::Literal; 9] = [
            &state.params, &state.m, &state.v, &step_lit,
            &graph.adj, &graph.feats, &graph.labels, &graph.mask, &lr_lit,
        ];
        let result =
            self.train_exe.execute::<&xla::Literal>(&args)?[0][0]
                .to_literal_sync()?;
        let mut parts = result.to_tuple()?;
        anyhow::ensure!(parts.len() == 5, "train_step returned {} outputs",
                        parts.len());
        let acc = scalar_f32(&parts[4])?;
        let loss = scalar_f32(&parts[3])?;
        state.v = parts.remove(2);
        state.m = parts.remove(1);
        state.params = parts.remove(0);
        Ok(StepOutput { loss, acc })
    }
}
