//! Offline API stub for the `xla` (xla_extension / PJRT) bindings.
//!
//! The build environment has no crates.io access and no libxla_extension,
//! so this crate provides the exact API surface `hulk::runtime` compiles
//! against. [`Literal`] is fully functional host-side (construction,
//! reshape, readback — enough for marshalling code and its tests); the
//! PJRT client/executable entry points return a descriptive error at
//! runtime, so every GNN path degrades to "artifacts unavailable" instead
//! of failing to build. The oracle-splitter paths — everything `hulk
//! scenarios` and the default benches run — never touch PJRT.
//!
//! Swapping in the real bindings is a one-line change in `rust/Cargo.toml`.

use std::fmt;
use std::path::Path;

/// Stub error type; implements `std::error::Error` so `?` converts it
/// into `anyhow::Error` exactly like the real crate's error does.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT is unavailable in this offline build (the vendored \
         `xla` crate is an API stub). Link the real xla_extension crate in \
         rust/Cargo.toml and run `make artifacts` to enable the GNN \
         runtime; the oracle-splitter paths work without it."
    ))
}

/// Element storage for [`Literal`]. Public only because trait signatures
/// reference it; treat as an implementation detail.
#[doc(hidden)]
#[derive(Clone, Debug, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Element types a [`Literal`] can hold.
pub trait NativeType: Copy {
    #[doc(hidden)]
    fn wrap(data: Vec<Self>) -> Data;
    #[doc(hidden)]
    fn unwrap(data: &Data) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(data: Vec<f32>) -> Data {
        Data::F32(data)
    }
    fn unwrap(data: &Data) -> Option<Vec<f32>> {
        match data {
            Data::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: Vec<i32>) -> Data {
        Data::I32(data)
    }
    fn unwrap(data: &Data) -> Option<Vec<i32>> {
        match data {
            Data::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// A host-side tensor (or tuple of tensors) with explicit dimensions.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            dims: vec![data.len() as i64],
            data: T::wrap(data.to_vec()),
        }
    }

    /// Reinterpret with new dimensions; the element count must match.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let expect: i64 = dims.iter().product();
        if self.element_count() as i64 != expect {
            return Err(Error(format!(
                "reshape: {} elements do not fit dims {:?}",
                self.element_count(),
                dims
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Number of elements (tuple literals report their arity).
    pub fn element_count(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::Tuple(t) => t.len(),
        }
    }

    /// Read back as a host vector of `T`; errors on type mismatch.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data)
            .ok_or_else(|| Error("literal element type mismatch".into()))
    }

    /// Destructure a tuple literal into its parts.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match &self.data {
            Data::Tuple(parts) => Ok(parts.clone()),
            _ => Err(Error("literal is not a tuple".into())),
        }
    }

    /// Build a tuple literal (execution results are tuples).
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal { dims: vec![parts.len() as i64], data: Data::Tuple(parts) }
    }

    /// Destructure a 1-tuple literal into its single part.
    pub fn to_tuple1(&self) -> Result<Literal> {
        let mut parts = self.to_tuple()?;
        if parts.len() != 1 {
            return Err(Error(format!(
                "expected 1-tuple, got {} parts",
                parts.len()
            )));
        }
        Ok(parts.remove(0))
    }
}

impl AsRef<Literal> for Literal {
    fn as_ref(&self) -> &Literal {
        self
    }
}

/// Parsed HLO module text (the AOT interchange format).
pub struct HloModuleProto {
    _text: String,
}

impl HloModuleProto {
    /// Read an HLO text artifact. Parsing is deferred to compilation,
    /// which the stub cannot perform.
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path.as_ref()).map_err(|e| {
            Error(format!("reading {}: {e}", path.as_ref().display()))
        })?;
        Ok(HloModuleProto { _text: text })
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// PJRT client handle. The stub cannot create one — `cpu()` reports how
/// to enable the real runtime.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "offline-stub".to_string()
    }

    pub fn compile(
        &self,
        _computation: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// A compiled executable. Unconstructible through the stub client, so
/// `execute` is unreachable in practice but still returns a clean error.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: AsRef<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// A device buffer returned by execution.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(lit.element_count(), 4);
        let sq = lit.reshape(&[2, 2]).unwrap();
        assert_eq!(sq.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(lit.reshape(&[3]).is_err());
        assert!(sq.to_vec::<i32>().is_err()); // type mismatch
    }

    #[test]
    fn i32_literals_work() {
        let lit = Literal::vec1(&[7i32, 8, 9]);
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![7, 8, 9]);
    }

    #[test]
    fn tuple_accessors_reject_non_tuples() {
        let lit = Literal::vec1(&[1.0f32]);
        assert!(lit.to_tuple().is_err());
        assert!(lit.to_tuple1().is_err());
    }

    #[test]
    fn tuple_roundtrip() {
        let t = Literal::tuple(vec![Literal::vec1(&[1.0f32])]);
        let inner = t.to_tuple1().unwrap();
        assert_eq!(inner.to_vec::<f32>().unwrap(), vec![1.0]);
        assert_eq!(t.to_tuple().unwrap().len(), 1);
    }

    #[test]
    fn client_reports_offline_stub() {
        let Err(err) = PjRtClient::cpu() else {
            panic!("stub must not build a PJRT client");
        };
        assert!(err.to_string().contains("offline"), "{err}");
    }
}
