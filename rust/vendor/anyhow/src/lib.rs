//! Offline stand-in for the `anyhow` crate, vendored because the build
//! environment has no crates.io access. Implements the subset of the real
//! API this workspace uses — `Error`, `Result`, the `anyhow!`/`bail!`/
//! `ensure!` macros, and the `Context` extension trait for `Result` and
//! `Option` — with the same semantics (single dynamic error type, `?`
//! conversion from any `std::error::Error`, context wrapping with a
//! "Caused by" chain in the `Debug` rendering).
//!
//! Swapping in the real crate is a one-line change in `rust/Cargo.toml`.

use std::error::Error as StdError;
use std::fmt;

/// A dynamic error: a message plus an optional source chain.
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Build an error from anything displayable (the `anyhow!` macro's
    /// single-expression form).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap `source` under a new context message (`Context::context`).
    pub fn wrap<M: fmt::Display>(
        message: M,
        source: Box<dyn StdError + Send + Sync + 'static>,
    ) -> Error {
        Error { msg: message.to_string(), source: Some(source) }
    }

    /// The outermost (most recent) context message.
    pub fn root_message(&self) -> &str {
        &self.msg
    }

    /// Iterate the source chain, outermost first (excluding this error's
    /// own message).
    pub fn chain(&self) -> impl Iterator<Item = &(dyn StdError + 'static)> {
        let mut next: Option<&(dyn StdError + 'static)> =
            self.source.as_deref().map(|s| s as &(dyn StdError + 'static));
        std::iter::from_fn(move || {
            let current = next?;
            next = current.source();
            Some(current)
        })
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let causes: Vec<String> =
            self.chain().map(|c| c.to_string()).collect();
        if !causes.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for cause in causes {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// `Error` deliberately does NOT implement `std::error::Error`, exactly
// like the real anyhow: that is what makes this blanket `From` coherent.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string(), source: Some(Box::new(e)) }
    }
}

/// `anyhow::Result<T>` — `std::result::Result` with `Error` as default.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to `Result` and `Option` errors.
pub trait Context<T> {
    /// Wrap the error (or `None`) under `context`.
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    /// Like [`Context::context`], but the message is built lazily.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T>
    for std::result::Result<T, E>
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| Error::wrap(context, Box::new(e)))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::wrap(f(), Box::new(e)))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string, a displayable value, or a
/// message literal (same three arms as the real macro).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($t:tt)+) => {
        if !($cond) {
            $crate::bail!($($t)+);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("gone"));
    }

    #[test]
    fn macro_forms() {
        let a: Error = anyhow!("plain message");
        assert_eq!(a.to_string(), "plain message");
        let msg = String::from("from a String");
        let b: Error = anyhow!(msg);
        assert_eq!(b.to_string(), "from a String");
        let c: Error = anyhow!("x = {}, y = {:?}", 1, "two");
        assert_eq!(c.to_string(), "x = 1, y = \"two\"");
    }

    #[test]
    fn bail_and_ensure() {
        fn f(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            if !flag {
                bail!("unreachable");
            }
            Ok(7)
        }
        assert_eq!(f(true).unwrap(), 7);
        assert_eq!(f(false).unwrap_err().to_string(), "flag was false");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("opening config").unwrap_err();
        assert_eq!(e.to_string(), "opening config");
        assert!(format!("{e:?}").contains("gone")); // cause in Debug

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing key {:?}", "n"))
            .unwrap_err();
        assert!(e.to_string().contains("missing key"));

        let some: Option<u32> = Some(3);
        assert_eq!(some.context("unused").unwrap(), 3);
    }

    #[test]
    fn chain_walks_sources() {
        let e = Error::wrap("outer", Box::new(io_err()));
        let chain: Vec<String> = e.chain().map(|c| c.to_string()).collect();
        assert_eq!(chain, vec!["gone".to_string()]);
    }
}
